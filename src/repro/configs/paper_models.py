"""The paper's own model families (LLaMA / OPT / Mistral), as configs.

Full-size versions are exercised only as extra dry-run material; the PPL
reproduction uses ``small_*`` variants trained from scratch (no pretrained
weights exist in this offline container — DESIGN.md §10).
"""

from .base import ModelConfig

LLAMA_7B = ModelConfig(
    name="llama-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    norm="rmsnorm",
    activation="swiglu",
    max_seq=4096,
)

OPT_6_7B = ModelConfig(
    name="opt-6.7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=16384,
    vocab_size=50272,
    head_dim=128,
    attention="gqa",
    pos_emb="learned",
    norm="layernorm",
    activation="gelu",
    max_seq=2048,
)

MISTRAL_7B = ModelConfig(
    name="mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    norm="rmsnorm",
    activation="swiglu",
    max_seq=32768,
)


def small_lm(
    name: str = "small-llama",
    family_of: ModelConfig = LLAMA_7B,
    num_layers: int = 4,
    d_model: int = 128,
    d_ff: int = 352,
    vocab_size: int = 512,
    num_heads: int = 4,
) -> ModelConfig:
    """Trainable-on-CPU analogue of a paper family (keeps norm/act/pos-emb)."""
    import dataclasses

    kv = num_heads
    if family_of.num_kv_heads and family_of.num_heads % family_of.num_kv_heads == 0:
        group = family_of.num_heads // family_of.num_kv_heads
        kv = max(1, num_heads // min(group, num_heads))
    return dataclasses.replace(
        family_of,
        name=name,
        num_layers=num_layers,
        d_model=d_model,
        d_ff=d_ff,
        vocab_size=vocab_size,
        num_heads=num_heads,
        num_kv_heads=kv,
        head_dim=d_model // num_heads,
        max_seq=512,
        dtype="float32",
    )
