"""jamba-v0.1-52b — Mamba+attention 1:7 interleave with MoE 16e top-2.

[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Jamba period-8
blocks: attention at index 4 of each 8-layer block, Mamba elsewhere; MoE on
every other layer (odd indices), dense MLP otherwise.  Sub-quadratic =>
long_500k applies.
"""

from .base import MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    attention="gqa",
    pos_emb="none",  # jamba uses no positional encoding (mamba provides order)
    norm="rmsnorm",
    activation="swiglu",
    mixer_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=14336,
        num_shared_experts=0,
        first_k_dense=1,
        moe_every=2,
    ),
    mamba=MambaConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    subquadratic=True,
    max_seq=1048576,
)
