"""chatglm3-6b — RoPE 2d (half-dim rotary), aggressive GQA kv=2.

[arXiv:2406.12793; hf]
28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    rotary_pct=0.5,  # ChatGLM's 2d rope rotates half of each head dim
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    max_seq=131072,
)
