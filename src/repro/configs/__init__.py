from .base import (
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SHAPE_CASES,
    ShapeCase,
    applicable_shapes,
)
from .paper_models import LLAMA_7B, MISTRAL_7B, OPT_6_7B, small_lm
from .registry import ALL, ASSIGNED, PAPER, get_config
