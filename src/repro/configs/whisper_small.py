"""whisper-small — encoder-decoder with stubbed conv/audio frontend.

[arXiv:2212.04356; unverified]
12L(dec)+12L(enc) d_model=768 12H d_ff=3072 vocab=51865, LayerNorm + GELU,
learned positions.  The conv frontend is a stub: input_specs() supplies
precomputed frame embeddings (B, encoder_seq, d_model) per the assignment.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    attention="gqa",
    pos_emb="learned",
    norm="layernorm",
    activation="gelu",
    encoder_layers=12,
    encoder_seq=1500,
    frontend="audio",
    max_seq=448 * 128,  # decoder positions stretched to cover assigned shapes
)
