"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""

from .base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,  # dense first layer FFN (moonlight: 8*1408)
    vocab_size=163840,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    rope_theta=50000.0,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        first_k_dense=1,
        moe_every=1,
    ),
    max_seq=131072,
)
