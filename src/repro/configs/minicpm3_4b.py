"""minicpm3-4b — dense with MLA.  [hf:openbmb/MiniCPM3-4B; hf]

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA q_lora 768, kv_lora 256,
qk nope 64 + rope 32, v 64.
"""

from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,
    attention="mla",
    pos_emb="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    max_seq=131072,
)
