"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]
24L d_model=2048 d_ff=7168 vocab=65536.  RWKV6 time-mix (64-dim heads,
data-dependent decay via LoRA) + channel-mix.  Sub-quadratic => long_500k.
"""

from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # 2048 / 64 rwkv heads
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    attention="none",
    pos_emb="none",
    norm="layernorm",
    activation="relu_sq",  # rwkv channel-mix uses squared relu
    mixer_pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    subquadratic=True,
    max_seq=1048576,
)
