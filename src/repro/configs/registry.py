"""Architecture registry: --arch <id> lookup for every launcher."""

from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from .chatglm3_6b import CONFIG as CHATGLM3_6B
from .deepseek_67b import CONFIG as DEEPSEEK_67B
from .deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from .jamba_v0_1_52b import CONFIG as JAMBA_V0_1_52B
from .llava_next_mistral_7b import CONFIG as LLAVA_NEXT_MISTRAL_7B
from .minicpm3_4b import CONFIG as MINICPM3_4B
from .moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from .paper_models import LLAMA_7B, MISTRAL_7B, OPT_6_7B
from .phi3_medium_14b import CONFIG as PHI3_MEDIUM_14B
from .rwkv6_1_6b import CONFIG as RWKV6_1_6B
from .whisper_small import CONFIG as WHISPER_SMALL

# The 10 assigned architectures.
ASSIGNED: Dict[str, ModelConfig] = {
    "moonshot-v1-16b-a3b": MOONSHOT_V1_16B_A3B,
    "deepseek-v3-671b": DEEPSEEK_V3_671B,
    "whisper-small": WHISPER_SMALL,
    "deepseek-67b": DEEPSEEK_67B,
    "phi3-medium-14b": PHI3_MEDIUM_14B,
    "minicpm3-4b": MINICPM3_4B,
    "chatglm3-6b": CHATGLM3_6B,
    "llava-next-mistral-7b": LLAVA_NEXT_MISTRAL_7B,
    "jamba-v0.1-52b": JAMBA_V0_1_52B,
    "rwkv6-1.6b": RWKV6_1_6B,
}

# Paper's own families (extra material).
PAPER: Dict[str, ModelConfig] = {
    "llama-7b": LLAMA_7B,
    "opt-6.7b": OPT_6_7B,
    "mistral-7b": MISTRAL_7B,
}

ALL: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER}


def get_config(arch: str) -> ModelConfig:
    if arch not in ALL:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ALL)}"
        )
    return ALL[arch]
