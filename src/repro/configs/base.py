"""Model configuration system.

One frozen dataclass covers every assigned architecture; family-specific
features hang off optional sub-configs.  ``reduced()`` produces the smoke-test
configuration (same family/topology, tiny dims) required by the assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_k_dense: int = 0  # leading layers use dense FFN (deepseek-v3: 3)
    moe_every: int = 1  # MoE on layers with (i - first_k_dense) % moe_every == 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0  # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # Attention / positions / norm / activation flavor.
    attention: str = "gqa"  # gqa | mla | none
    pos_emb: str = "rope"  # rope | learned | none
    rotary_pct: float = 1.0  # chatglm3 rotates half the head dim
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # Mixer pattern for hybrid models: e.g. jamba = attention on every 8th
    # layer, mamba elsewhere.  "attn" | "mamba" | "rwkv".
    mixer_pattern: Tuple[str, ...] = ("attn",)  # cycled over layers

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # Encoder-decoder (whisper): encoder_layers > 0 enables it.
    encoder_layers: int = 0
    encoder_seq: int = 1500  # audio frames after the (stubbed) conv frontend

    # Modality frontend stub: none | audio | vision.
    frontend: str = "none"
    num_patches: int = 576  # llava anyres base tile

    max_seq: int = 131072
    dtype: str = "bfloat16"
    # Sub-quadratic? (determines long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def mixer_of(self, layer: int) -> str:
        return self.mixer_pattern[layer % len(self.mixer_pattern)]

    def ffn_of(self, layer: int) -> str:
        if self.moe is None:
            return "mlp"
        if layer < self.moe.first_k_dense:
            return "mlp"
        if (layer - self.moe.first_k_dense) % self.moe.moe_every == 0:
            return "moe"
        return "mlp"

    def layer_specs(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, ffn) per decoder layer — drives scan-stack grouping."""
        return tuple(
            (self.mixer_of(i), self.ffn_of(i)) for i in range(self.num_layers)
        )

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/topology, tiny dimensions."""
        scale_heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, scale_heads)) if self.num_kv_heads else 0
        if self.num_kv_heads and self.num_heads % self.num_kv_heads == 0:
            # preserve GQA grouping structure (e.g. kv=2 for chatglm3)
            group = self.num_heads // self.num_kv_heads
            kv = max(1, scale_heads // min(group, scale_heads))
        pattern_len = len(self.mixer_pattern)
        n_layers = max(2 * pattern_len, 2)
        if self.moe is not None:
            n_layers = max(n_layers, self.moe.first_k_dense + 2 * self.moe.moe_every)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=32,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                # Lossless capacity so prefill+decode == full forward in the
                # smoke tests (capacity dropping is batch-composition
                # dependent by design).
                capacity_factor=8.0,
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(
                q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                qk_rope_head_dim=4, v_head_dim=8,
            )
        mamba = None
        if self.mamba is not None:
            mamba = MambaConfig(d_inner=64, d_state=4, d_conv=4, dt_rank=4)
        rwkv = None
        if self.rwkv is not None:
            rwkv = RWKVConfig(head_dim=8, decay_lora=8, mix_lora=4)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=32,
            num_heads=scale_heads,
            num_kv_heads=kv,
            d_ff=64,
            vocab_size=256,
            head_dim=8,
            moe=moe,
            mla=mla,
            mamba=mamba,
            rwkv=rwkv,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            num_patches=8,
            max_seq=128,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPE_CASES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the four assigned shapes apply to this architecture.

    long_500k requires sub-quadratic sequence mixing (SSM / hybrid); it is
    skipped for pure full-attention archs per the assignment (the skip is
    recorded in EXPERIMENTS.md §Dry-run).
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return tuple(shapes)
