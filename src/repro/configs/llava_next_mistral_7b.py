"""llava-next-mistral-7b — mistral-7b backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  The vision tower /
anyres tiling is a stub: input_specs() supplies precomputed patch embeddings
(B, num_patches, d_model) which are prepended to the token embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    rope_theta=1000000.0,
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision",
    num_patches=576,
    max_seq=131072,
)
