"""deepseek-v3-671b — MLA + MoE 256e top-8, 1 shared expert.

[arXiv:2412.19437; hf]
61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 256e top-8,
MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128), first 3 layers
dense FFN (d_ff 18432).  MTP head omitted (single-token objective; noted in
DESIGN.md — it is a training-objective add-on orthogonal to compression).
"""

from .base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,  # dense layers 0-2
    vocab_size=129280,
    head_dim=128,
    attention="mla",
    pos_emb="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_k_dense=3,
        moe_every=1,
        capacity_factor=1.25,
    ),
    max_seq=131072,
)
