"""deepseek-67b — dense llama-arch.  [arXiv:2401.02954; hf]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    attention="gqa",
    pos_emb="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="swiglu",
    max_seq=131072,
)
