"""Calibration runner: collect Grams over a calibration stream.

Mirrors the paper's protocol: N samples (default 256, as in §4) from the
calibration domain; one forward pass per batch with taps enabled; Grams
accumulate in float64 on host.  The forward is jitted once per shape.
"""

from __future__ import annotations

import logging
from typing import Dict, Iterable, Optional

import jax
import numpy as np

from repro.core.compress import GramStore
from repro.models.api import Model

from .gram import accumulate_taps

logger = logging.getLogger(__name__)


def collect_grams(
    model: Model,
    params,
    batches: Iterable[Dict[str, np.ndarray]],
    max_batches: Optional[int] = None,
    telemetry=None,
) -> GramStore:
    """Accumulate calibration Grams; ``telemetry`` (a
    ``repro.obs.compression.CompressionTelemetry``) observes without
    changing the store: per-batch row counts stream in during the pass and
    the per-tap activation statistics (absmean distribution, outlier
    fractions, Gram condition numbers) are computed exactly once over the
    final accumulated store."""
    store = GramStore()

    def fwd(p, batch):
        taps: Dict = {}
        kwargs = {}
        if model.cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        elif "patches" in batch:
            kwargs["patches"] = batch["patches"]
        model.apply(p, batch["tokens"], mode="train", taps=taps, **kwargs)
        return taps

    jitted = jax.jit(fwd)
    n = 0
    for i, batch in enumerate(batches):
        if max_batches is not None and i >= max_batches:
            break
        taps = jitted(params, batch)
        accumulate_taps(store, taps, telemetry=telemetry)
        n += 1
    logger.info("calibration: %d batches, %d gram keys", n, len(list(store.keys())))
    if telemetry is not None and telemetry.enabled:
        telemetry.on_calib_store(store)
    return store


def calibration_batches(
    vocab: int, domain: str, n_samples: int = 256, batch: int = 16, seq: int = 128,
    seed: int = 7,
):
    """The paper's 256-sample calibration set, as a batch iterator."""
    from repro.data.synth import DomainSampler

    sampler = DomainSampler(vocab, seed=seed)
    n_batches = max(1, n_samples // batch)
    for _ in range(n_batches):
        yield {"tokens": sampler.batch(domain, batch, seq)}
