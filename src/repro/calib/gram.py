"""Streaming Gram accumulation from model activation taps.

For each tapped activation x (.., n) we accumulate
    G += sum over rows of x^T x      (n, n) fp32 on device, fp64 on host
    a += sum |x|                      (n,)
    c += row count
jitted per batch; the host store sums across batches in float64.

Tap names from unrolled scan groups look like  "g0/rep3/sub0.mlp.in";
``normalize_tap`` rewrites them to the GramStore key "g0/sub0.mlp.in/3"
that compression targets look up (plus the shared fallback key
"g0/sub0.mlp.in" accumulated over all layers).

MoE expert buffers are tapped as (E, C, D) with zero-padded slots (they
contribute nothing to the Gram); per-expert keys get "/e{idx}" suffixes.
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import GramStore

_REP_RE = re.compile(r"/rep(\d+)/")


def normalize_tap(name: str) -> Tuple[str, str]:
    """Returns (base_key, slice_suffix).  base_key has the rep index moved
    out; suffix is "" or "3"."""
    m = _REP_RE.search(name)
    if not m:
        return name, ""
    base = _REP_RE.sub("/", name)
    return base, m.group(1)


@jax.jit
def gram_update(x: jax.Array):
    """x: (..., n) -> (G (n,n) f32, absmean-sum (n,), count)."""
    n = x.shape[-1]
    flat = x.reshape(-1, n).astype(jnp.float32)
    g = jnp.matmul(flat.T, flat, precision=jax.lax.Precision.HIGHEST)
    a = jnp.sum(jnp.abs(flat), axis=0)
    c = jnp.asarray(flat.shape[0], jnp.float32)
    return g, a, c


@jax.jit
def expert_gram_update(buf: jax.Array):
    """buf: (E, C, n) zero-padded -> per-expert (E,n,n), (E,n), counts (E,)."""
    e, c, n = buf.shape
    b = buf.astype(jnp.float32)
    g = jnp.einsum("ecn,ecm->enm", b, b, precision=jax.lax.Precision.HIGHEST)
    a = jnp.sum(jnp.abs(b), axis=1)
    cnt = jnp.sum(jnp.any(b != 0, axis=-1), axis=1).astype(jnp.float32)
    return g, a, cnt


def accumulate_taps(
    store: GramStore,
    taps: Dict[str, jax.Array],
    telemetry=None,
) -> None:
    """Fold one batch of taps into the host GramStore.

    ``telemetry`` (``repro.obs.compression.CompressionTelemetry``) gets the
    cheap per-batch signal only — rows folded per normalized tap; the
    expensive per-tap statistics (outlier fractions, Gram condition) run
    once at the end of calibration in ``runner.collect_grams``."""
    tap_rows: Dict[str, float] = {}
    observing = telemetry is not None and telemetry.enabled
    for name, x in taps.items():
        base, suffix = normalize_tap(name)
        if base.endswith(("expert_buf", "expert_mid")):
            g, a, cnt = expert_gram_update(x)
            g = np.asarray(g, np.float64)
            a = np.asarray(a, np.float64)
            cnt = np.asarray(cnt, np.float64)
            for ei in range(g.shape[0]):
                key = f"{base}/{suffix}/{ei}" if suffix else f"{base}/{ei}"
                store.update(key, g[ei], a[ei], float(cnt[ei]))
            # Shared fallback across experts (+ layers).
            store.update(base, g.sum(0), a.sum(0), float(cnt.sum()))
            if observing:
                tap_rows[base] = tap_rows.get(base, 0.0) + float(cnt.sum())
        else:
            g, a, c = gram_update(x)
            g = np.asarray(g, np.float64)
            a = np.asarray(a, np.float64)
            if suffix:
                store.update(f"{base}/{suffix}", g, a, float(c))
            store.update(base, g, a, float(c))
            if observing:
                tap_rows[base] = tap_rows.get(base, 0.0) + float(c)
    if observing:
        telemetry.on_calib_batch(tap_rows)
