from .sharding import (
    NONE_PARALLEL,
    Parallelism,
    make_parallelism,
    param_pspec,
    param_pspecs,
    param_shardings,
)
