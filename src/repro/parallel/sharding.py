"""Sharding rules: DP x TP (x EP) partition specs for every param & activation.

Mesh axes:
  single pod: ("data", "model")           — 16 x 16 (v5e pod of 256)
  multi-pod:  ("pod", "data", "model")    — pods compose with data for DP;
                                            scaling to 1000+ nodes = growing
                                            "pod" (pure DP replication), so
                                            these specs are topology-stable.

Param rules (Megatron-style TP over "model"):
  embeddings (V, D)           -> (tp, None)        vocab-sharded
  unembed    (D, V)           -> (None, tp)
  attn  wq/wk/wv (D, H*hd)    -> (None, tp)        head-sharded (GSPMD pads
                                                   non-divisible head counts)
  attn  wo (H*hd, D)          -> (tp, None)        row-parallel (psum)
  mlp   wi/wg (D, F)          -> (None, tp)
  mlp   wo (F, D)             -> (tp, None)
  moe   experts (E, D, F)     -> (tp, None, None)  expert-parallel
  mamba column/row splits over d_inner; rwkv over heads.

Factored (compressed) params inherit the dense kernel's boundary shardings:
  u  (in, k)  -> (in_axis, None)
  v  (k, out) -> (None, out_axis)
so a row-parallel factored layer all-reduces a rank-k partial instead of the
full d_model — the compression shrinks the TP collective (EXPERIMENTS.md
§Perf).

Optimizer state (ZeRO-1): moments additionally sharded over the DP axes on
their largest replicated dim — see repro/optim/adamw.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Parallelism:
    """Carries the mesh + axis names through model construction."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"

    @property
    def active(self) -> bool:
        return self.mesh is not None

    @property
    def dp(self):  # spec entry for batch dims
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def constrain(self, x: jax.Array, *spec) -> jax.Array:
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )


NONE_PARALLEL = Parallelism()


def make_parallelism(mesh: Optional[Mesh]) -> Parallelism:
    if mesh is None:
        return NONE_PARALLEL
    names = mesh.axis_names
    if "pod" in names:
        return Parallelism(mesh, ("pod", "data"), "model")
    return Parallelism(mesh, ("data",), "model")


# --------------------------------------------------------------- param rules

# (path regex) -> (in_axis, out_axis) for linear-like leaves.  Specific
# rules MUST precede generic ones (first match wins).
_LINEAR_RULES: Sequence[Tuple[str, Tuple[Optional[str], Optional[str]]]] = (
    # rwkv / mamba / moe / mla specifics first
    (r"rwkv_c/wk$", (None, "model")),
    (r"rwkv_c/wv$", ("model", None)),
    (r"rwkv_c/wr$", (None, None)),
    (r"rwkv_t/(wr|wk|wv|wg)$", (None, "model")),
    (r"rwkv_t/wo$", ("model", None)),
    (r"mamba/in_proj$", (None, "model")),
    (r"mamba/x_proj$", ("model", None)),
    (r"mamba/out_proj$", ("model", None)),
    (r"(^|/)router$", (None, None)),
    (r"(^|/)wq_a$", (None, None)),
    (r"(^|/)wq_b$", (None, "model")),
    (r"(^|/)wkv_a$", (None, None)),
    (r"(^|/)wkv_b$", (None, "model")),
    # generic transformer projections
    (r"(^|/)unembed$", (None, "model")),
    (r"(^|/)(wq|wk|wv)$", (None, "model")),
    (r"(^|/)wo$", ("model", None)),
    (r"(^|/)(wi|wg)$", (None, "model")),
)

# Non-linear leaves: path regex -> spec (without stacked prefix).
_LEAF_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"(^|/)embed/table$", ("model", None)),
    (r"(^|/)pos/table$", (None, None)),
    (r"experts/(wi|wg|wo)/kernel$", ("model", None, None)),
    (r"experts/(wi|wg|wo)/(u|v|u2|v2)$", ("model", None, None)),
    (r"mamba/conv/w$", (None, "model")),
    (r"mamba/conv/b$", ("model",)),
    (r"mamba/dt_proj/kernel$", (None, "model")),
    (r"mamba/dt_proj/bias$", ("model",)),
    (r"mamba/a_log$", ("model", None)),
    (r"mamba/d_skip$", ("model",)),
    (r"rwkv_t/bonus$", ("model", None)),
    (r"rwkv_t/(ln_scale|ln_bias)$", ("model",)),
)


def _match_linear(path: str):
    for pat, axes in _LINEAR_RULES:
        if re.search(pat, path):
            return axes
    return None


def _match_leaf(path: str, ndim: int):
    for pat, spec in _LEAF_RULES:
        if re.search(pat, path):
            return spec
    return None


def param_pspec(path: Tuple[str, ...], leaf, fsdp_axes=None) -> P:
    """PartitionSpec for one param leaf given its pytree path.

    ``fsdp_axes``: additionally shard each 2D+ weight over the DP axes on
    its first TP-free dim (ZeRO-3/FSDP storage; XLA inserts per-layer
    all-gather at use and reduce-scatter on grads).  Required to fit the
    671B-class archs (EXPERIMENTS.md §Dry-run memory table).
    """
    ndim = len(leaf.shape)
    joined = "/".join(path)
    parent = "/".join(path[:-1])
    key = path[-1]

    def with_fsdp(entries):
        if not fsdp_axes or ndim < 2:
            return P(*entries)
        entries = list(entries)
        dp = tuple(fsdp_axes) if len(fsdp_axes) > 1 else fsdp_axes[0]
        # Largest free dim gets the DP axes (skip tiny dims).
        free = [
            i for i, e in enumerate(entries)
            if e is None and leaf.shape[i] >= 128
        ]
        if free:
            target = max(free, key=lambda i: leaf.shape[i])
            entries[target] = dp
        return P(*entries)

    spec = _match_leaf(joined, ndim)
    if spec is not None:
        pad = ndim - len(spec)
        return with_fsdp([None] * pad + list(spec))

    if key in ("kernel", "u", "v", "u2", "v2", "table"):
        axes = _match_linear(parent)
        if axes is None:
            return P()  # replicate unknown linears
        in_ax, out_ax = axes
        if key == "kernel":
            mat = (in_ax, out_ax)
        elif key in ("u", "u2"):
            # Factored params: shard u on its INPUT dim and v on its OUTPUT
            # dim regardless of the dense kernel's orientation — inheriting
            # the dense boundary would leave u fully replicated for every
            # column-parallel layer (measured: 2.7x the dense per-device
            # bytes at ratio 0.3!).  Cost: one rank-width psum per factored
            # column-parallel matmul — k/d_model of the dense TP collective
            # (§Perf pair C, iteration C1).
            mat = (None, None) if (in_ax is None and out_ax is None) else ("model", None)
        else:  # v / v2
            mat = (None, None) if (in_ax is None and out_ax is None) else (None, "model")
        pad = ndim - 2
        return with_fsdp([None] * pad + list(mat))

    return P()  # norms, biases, scalars: replicated


def tree_paths(tree, prefix=()) -> Dict[Tuple[str, ...], Any]:
    out = {}
    if isinstance(tree, Mapping):
        for k, v in tree.items():
            out.update(tree_paths(v, prefix + (str(k),)))
    else:
        out[prefix] = tree
    return out


def param_shardings(params_shape, mesh: Mesh, fsdp_axes=None):
    """Pytree of NamedSharding matching a params (shape) pytree."""

    def walk(tree, prefix=()):
        if isinstance(tree, Mapping):
            return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
        return NamedSharding(mesh, param_pspec(prefix, tree, fsdp_axes))

    return walk(params_shape)


def param_pspecs(params_shape, fsdp_axes=None):
    """Pytree of raw PartitionSpec (mesh-independent)."""

    def walk(tree, prefix=()):
        if isinstance(tree, Mapping):
            return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
        return param_pspec(prefix, tree, fsdp_axes)

    return walk(params_shape)


def moe_shard_specs(moe_params_shape) -> Any:
    """in_specs for the MoE shard_map: experts sharded on 'model', shared
    experts TP-sliced, router replicated."""

    def walk(tree, prefix=()):
        if isinstance(tree, Mapping):
            return {k: walk(v, prefix + (str(k),)) for k, v in tree.items()}
        return param_pspec(prefix, tree)

    return walk(moe_params_shape)
