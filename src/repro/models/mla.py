"""Multi-head Latent Attention (deepseek-v3 / minicpm3).

MLA compresses K/V into a small latent c_kv (kv_lora_rank) plus a shared
rope key; the KV cache stores only (c_kv, k_rope) — a ~10-50x cache
reduction vs GQA.

Two execution paths:
  * naive (train/prefill): expand K/V from the latent per token — matches the
    reference formulation, best for large-S matmuls.
  * absorbed (decode): fold W_uk into the query and W_uv into the output so
    attention runs directly in latent space — avoids re-expanding a 32k-token
    cache for every generated token.  This is the TPU-friendly decode path
    (hillclimb candidate; see EXPERIMENTS.md §Perf).

NSVD composes with MLA by treating each projection (wq_a, wq_b, wkv_a,
wkv_b, wo) as an independent compressible matrix (DESIGN.md §7).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import linear, linear_init, norm_apply, norm_init
from .lowrank_utils import dense_kernel

NEG_INF = -1e30


def mla_init(key, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": linear_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": norm_init("rmsnorm", m.q_lora_rank, dtype),
        "wq_b": linear_init(ks[1], m.q_lora_rank, h * qk_dim, dtype),
        "wkv_a": linear_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": norm_init("rmsnorm", m.kv_lora_rank, dtype),
        "wkv_b": linear_init(
            ks[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype
        ),
        "wo": linear_init(ks[4], h * m.v_head_dim, d, dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate full last dim; x (..., S, dim) or (..., S, H, dim)."""
    dim = x.shape[-1]
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    if x.ndim == positions.ndim + 1:  # (B, S, dim)
        ang = positions[..., None].astype(jnp.float32) * inv_freq
    else:  # (B, S, H, dim)
        ang = positions[..., None, None].astype(jnp.float32) * inv_freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


def _project_q(params, x, cfg: ModelConfig, positions):
    m = cfg.mla
    h = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = norm_apply(params["q_norm"], linear(params["wq_a"], x))
    q = linear(params["wq_b"], cq).reshape(*x.shape[:-1], h, qk)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = _rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, cfg: ModelConfig, positions):
    m = cfg.mla
    kv_a = linear(params["wkv_a"], x)
    c_kv = norm_apply(params["kv_norm"], kv_a[..., : m.kv_lora_rank])
    k_rope = _rope(kv_a[..., m.kv_lora_rank :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    mode: str = "causal",
    cache: Optional[Dict] = None,
    cache_len: Optional[jax.Array] = None,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
) -> Tuple[jax.Array, Optional[Dict]]:
    m = cfg.mla
    h = cfg.num_heads
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    b, s, _ = x.shape

    if taps is not None:
        taps[f"{tap_prefix}.in"] = x

    q_nope, q_rope = _project_q(params, x, cfg, positions)
    c_kv_new, k_rope_new = _project_kv_latent(params, x, cfg, positions)
    if taps is not None:
        taps[f"{tap_prefix}.q_lora_in"] = norm_apply(
            params["q_norm"], linear(params["wq_a"], x)
        )
        taps[f"{tap_prefix}.kv_lora_in"] = c_kv_new

    new_cache = None
    if mode == "decode":
        assert cache is not None and s == 1
        idx = cache_len
        rows = jnp.arange(b)
        c_kv = cache["c_kv"].at[rows, idx].set(c_kv_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, idx].set(
            k_rope_new[:, 0].astype(cache["k_rope"].dtype)
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        out = _absorbed_attention(params, q_nope, q_rope, c_kv, k_rope, cfg, idx, scale)
    else:
        # Naive expanded path.
        kv = linear(params["wkv_b"], c_kv_new).reshape(
            b, s, h, m.qk_nope_head_dim + m.v_head_dim
        )
        k_nope = kv[..., : m.qk_nope_head_dim]
        v = kv[..., m.qk_nope_head_dim :]
        k_rope_bcast = jnp.broadcast_to(
            k_rope_new[:, :, None, :], (b, s, h, m.qk_rope_head_dim)
        )
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope, k_rope_bcast], -1)
        scores = (
            jnp.einsum("bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32)
            * scale
        )
        causal = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(causal[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
        if cache is not None:
            t_max = cache["c_kv"].shape[1]
            new_cache = {
                "c_kv": jnp.pad(c_kv_new, [(0, 0), (0, t_max - s), (0, 0)]).astype(
                    cache["c_kv"].dtype
                ),
                "k_rope": jnp.pad(k_rope_new, [(0, 0), (0, t_max - s), (0, 0)]).astype(
                    cache["k_rope"].dtype
                ),
            }
        out = out.reshape(b, s, h * m.v_head_dim)
        if taps is not None:
            taps[f"{tap_prefix}.out_in"] = out
        return linear(params["wo"], out), new_cache

    out = out.reshape(b, s, h * m.v_head_dim)
    if taps is not None:
        taps[f"{tap_prefix}.out_in"] = out
    return linear(params["wo"], out), new_cache


def _absorbed_attention(params, q_nope, q_rope, c_kv, k_rope, cfg, idx, scale):
    """Decode attention in latent space (W_uk/W_uv absorbed).

    q_nope: (B, 1, H, nope), c_kv: (B, T, R), k_rope: (B, T, r).
    """
    m = cfg.mla
    h = cfg.num_heads
    wkv_b = dense_kernel(params["wkv_b"])  # (R, H*(nope+v))
    wkv_b = wkv_b.reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_head_dim]  # (R, H, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim :]  # (R, H, v)

    # Fold W_uk into q: q_eff (B, 1, H, R)
    q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    scores = jnp.einsum(
        "bshr,btr->bhst", q_eff, c_kv, preferred_element_type=jnp.float32
    )
    scores += jnp.einsum(
        "bshr,btr->bhst", q_rope, k_rope, preferred_element_type=jnp.float32
    )
    scores *= scale
    t_max = c_kv.shape[1]
    valid = jnp.arange(t_max)[None, :] <= idx[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs.astype(c_kv.dtype), c_kv)  # (B,1,H,R)
    return jnp.einsum("bshr,rhv->bshv", ctx, w_uv)  # (B,1,H,v)
