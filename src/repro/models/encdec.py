"""Encoder-decoder LM (whisper-family) with stubbed audio frontend.

``frames`` are precomputed post-conv frame embeddings (B, T_enc, d_model)
per the assignment — the conv1d/mel frontend is a stub.  The decoder adds
cross-attention to every block; decode reuses prefilled cross K/V.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NONE_PARALLEL, Parallelism

from .blocks import StackGroup, group_apply, group_cache_init, group_init
from .layers import (
    embed,
    embedding_init,
    learned_pos,
    learned_pos_init,
    linear_init,
    norm_apply,
    norm_init,
    unembed,
)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, par: Parallelism = NONE_PARALLEL,
                 remat: bool = False, unroll: bool = False):
        assert cfg.is_encdec
        self.cfg = cfg
        self.par = par
        self.remat = remat
        self.unroll = unroll
        self.dtype = getattr(jnp, cfg.dtype)
        # Encoder and decoder are each a single uniform stack.
        self.enc_group = StackGroup((("gqa", "mlp"),), cfg.encoder_layers, 0)
        self.dec_group = StackGroup((("gqa", "mlp"),), cfg.num_layers, 0)

    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 7)
        return {
            "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, self.dtype),
            "pos_dec": learned_pos_init(ks[1], cfg.max_seq, cfg.d_model, self.dtype),
            "pos_enc": learned_pos_init(ks[2], cfg.encoder_seq, cfg.d_model, self.dtype),
            "encoder": group_init(ks[3], self.enc_group, cfg, self.dtype, cross=False),
            "enc_norm": norm_init(cfg.norm, cfg.d_model, self.dtype),
            "decoder": group_init(ks[4], self.dec_group, cfg, self.dtype, cross=True),
            "final_norm": norm_init(cfg.norm, cfg.d_model, self.dtype),
            "unembed": linear_init(ks[5], cfg.d_model, cfg.vocab_size, self.dtype),
        }

    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_quant: bool = False) -> Dict:
        dtype = dtype or self.dtype
        return {
            "decoder": group_cache_init(
                self.dec_group, self.cfg, batch, max_len, dtype, cross=True,
                kv_quant=kv_quant,
            )
        }

    def encode(self, params, frames: jax.Array, taps=None) -> jax.Array:
        cfg = self.cfg
        b, t, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        x = frames.astype(self.dtype) + learned_pos(params["pos_enc"], pos).astype(
            self.dtype
        )
        x = self.par.constrain(x, self.par.dp, None, None)
        x, _, _ = group_apply(
            params["encoder"], x, self.enc_group, cfg,
            positions=pos, mode="train", par=self.par,
            taps=taps, tap_group="enc", encoder=True,
            remat=self.remat, unroll=self.unroll,
        )
        return norm_apply(params["enc_norm"], x)

    def apply(
        self,
        params: Mapping[str, Any],
        tokens: jax.Array,
        *,
        frames: Optional[jax.Array] = None,
        memory: Optional[jax.Array] = None,
        mode: str = "train",
        cache: Optional[Dict] = None,
        cache_len: Optional[jax.Array] = None,
        taps: Optional[Dict] = None,
    ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
        """Returns (logits, new_cache, aux).  For train/prefill pass
        ``frames``; decode uses the prefilled cross-K/V cache instead."""
        cfg = self.cfg
        b, s = tokens.shape
        if mode != "decode" and memory is None:
            memory = self.encode(params, frames, taps=taps)

        if mode == "decode":
            positions = cache_len[:, None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        x = embed(params["embed"], tokens).astype(self.dtype)
        x = x + learned_pos(params["pos_dec"], positions).astype(x.dtype)
        x = self.par.constrain(x, self.par.dp, None, None)

        x, new_cache, aux = group_apply(
            params["decoder"], x, self.dec_group, cfg,
            positions=positions, mode=mode,
            cache=None if cache is None else cache.get("decoder"),
            cache_len=cache_len, memory=memory,
            par=self.par, taps=taps, tap_group="dec",
            remat=self.remat and mode == "train",
            unroll=self.unroll,
        )
        x = norm_apply(params["final_norm"], x)
        logits = unembed(params["unembed"], x)
        logits = self.par.constrain(logits, self.par.dp, None, "model")
        return logits, ({"decoder": new_cache} if new_cache is not None else None), aux

    def compressible_targets(self):
        from repro.core.plan import TargetSpec

        cfg = self.cfg
        d = cfg.d_model
        hq = cfg.num_heads * cfg.head_dim
        targets = []

        def add(path, in_dim, out_dim, tap, stacked):
            targets.append(TargetSpec(path=path, in_dim=in_dim, out_dim=out_dim,
                                      gram_key=tap, stacked=stacked))

        for side, group, n in (
            ("encoder", self.enc_group, cfg.encoder_layers),
            ("decoder", self.dec_group, cfg.num_layers),
        ):
            tapg = "enc" if side == "encoder" else "dec"
            rep = (n,) if n > 1 else ()
            base = (side,) if n == 1 else (side,)
            tap = f"{tapg}/sub0"
            add(base + ("sub0", "attn", "wq"), d, hq, f"{tap}.attn.in", rep)
            add(base + ("sub0", "attn", "wk"), d, hq, f"{tap}.attn.in", rep)
            add(base + ("sub0", "attn", "wv"), d, hq, f"{tap}.attn.in", rep)
            add(base + ("sub0", "attn", "wo"), hq, d, f"{tap}.attn.out_in", rep)
            if side == "decoder":
                add(base + ("sub0", "cross", "wq"), d, hq, f"{tap}.cross.in", rep)
                add(base + ("sub0", "cross", "wk"), d, hq, f"{tap}.cross.kv_in", rep)
                add(base + ("sub0", "cross", "wv"), d, hq, f"{tap}.cross.kv_in", rep)
                add(base + ("sub0", "cross", "wo"), hq, d, f"{tap}.cross.out_in", rep)
            add(base + ("sub0", "mlp", "wi"), d, cfg.d_ff, f"{tap}.mlp.in", rep)
            add(base + ("sub0", "mlp", "wo"), cfg.d_ff, d, f"{tap}.mlp.mid", rep)
        return targets
