"""Shared model layers: norms, rotary embeddings, MLPs, embeddings.

Pure-functional: every layer is (init_fn, apply_fn) over plain dict pytrees.
All linear layers route through ``repro.core.lowrank.linear_apply`` so that
compressed (factored) parameters are drop-in.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping

import jax
import jax.numpy as jnp

from repro.core.lowrank import linear_apply


def _dtype(name: str):
    return getattr(jnp, name)


# ---------------------------------------------------------------- linear

def linear_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    std = scale / (in_dim ** 0.5)
    return {"kernel": (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std).astype(dtype)}


def linear(params: Mapping[str, Any], x: jax.Array) -> jax.Array:
    return linear_apply(params, x)


# ---------------------------------------------------------------- norms

def norm_init(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    raise ValueError(kind)


def norm_apply(params: Mapping[str, Any], x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, rotary_pct: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot_dim = int(head_dim * rotary_pct)
    rot_dim -= rot_dim % 2
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """Rotate the leading 2*len(inv_freq) features of the last dim.

    x: (..., S, H, hd) or (..., H, hd) broadcast against positions (..., S)
    positions: (B, S) int32.
    """
    rot = 2 * inv_freq.shape[0]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    # angles: (B, S, 1, rot/2)
    ang = positions[..., None, None].astype(jnp.float32) * inv_freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------- MLP

def mlp_init(key, cfg_activation: str, d_model: int, d_ff: int, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"wo": linear_init(ks[2], d_ff, d_model, dtype)}
    if cfg_activation == "swiglu":
        p["wi"] = linear_init(ks[0], d_model, d_ff, dtype)
        p["wg"] = linear_init(ks[1], d_model, d_ff, dtype)
    else:
        p["wi"] = linear_init(ks[0], d_model, d_ff, dtype)
    return p


def mlp_apply(params: Mapping[str, Any], x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(linear(params["wg"], x)) * linear(params["wi"], x)
    elif activation == "gelu":
        h = jax.nn.gelu(linear(params["wi"], x))
    elif activation == "relu_sq":
        h = jnp.square(jax.nn.relu(linear(params["wi"], x)))
    else:
        raise ValueError(activation)
    return linear(params["wo"], h)


def mlp_taps(params: Mapping[str, Any], x: jax.Array, activation: str, taps: Dict, prefix: str):
    """Forward with activation taps for calibration (records linear inputs)."""
    taps[f"{prefix}.in"] = x
    if activation == "swiglu":
        h = jax.nn.silu(linear(params["wg"], x)) * linear(params["wi"], x)
    elif activation == "gelu":
        h = jax.nn.gelu(linear(params["wi"], x))
    elif activation == "relu_sq":
        h = jnp.square(jax.nn.relu(linear(params["wi"], x)))
    else:
        raise ValueError(activation)
    taps[f"{prefix}.mid"] = h
    return linear(params["wo"], h)


# ---------------------------------------------------------------- embeddings

def embedding_init(key, vocab: int, dim: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)}


def embed(params: Mapping[str, Any], tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Mapping[str, Any], x: jax.Array) -> jax.Array:
    """Logits; params either a tied embedding table or an output projection."""
    if "table" in params:
        return jnp.einsum("...d,vd->...v", x, params["table"])
    return linear(params, x)


def learned_pos_init(key, max_seq: int, dim: int, dtype):
    return {"table": (jax.random.normal(key, (max_seq, dim), jnp.float32) * 0.02).astype(dtype)}


def learned_pos(params: Mapping[str, Any], positions: jax.Array) -> jax.Array:
    # Clip: assigned decode shapes can exceed the family's native max
    # positions; learned tables saturate rather than crash (documented).
    pos = jnp.minimum(positions, params["table"].shape[0] - 1)
    return jnp.take(params["table"], pos, axis=0)
