"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Faithful structure per arXiv:2404.05892:
  * ddlerp token-shift: x_i = x + (x_prev - x) * (mu_i + lora_i(lerp(x)))
    for i in {w, k, v, r, g}
  * data-dependent decay: w_t = exp(-exp(w0 + tanh(xw W_d1) W_d2))
  * per-head recurrence on state S (hd x hd):
      out_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
      S_t   = diag(w_t) S_{t-1} + k_t^T v_t
  * group-norm over heads, gated by silu(g), then output projection
  * channel-mix: token-shifted squared-relu FFN with receptance gate

Sequence path: lax.scan over time (the Pallas kernel implements the chunked
form; see repro/kernels/rwkv6).  Decode: single step against the
{"shift","state"} cache — O(1) per token, which is why rwkv6 runs the
long_500k cell.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import linear, linear_init

Array = jax.Array

MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_time_mix_init(key, cfg: ModelConfig, dtype) -> Dict:
    r = cfg.rwkv
    d = cfg.d_model
    n_heads = d // r.head_dim
    ks = jax.random.split(key, 16)
    p: Dict[str, Any] = {
        "mu_x": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mix_w1": (jax.random.normal(ks[1], (d, 5 * r.mix_lora), jnp.float32) * 0.02).astype(dtype),
        "mix_w2": (jax.random.normal(ks[2], (5, r.mix_lora, d), jnp.float32) * 0.02).astype(dtype),
        "mu": (jax.random.uniform(ks[3], (5, d)) * 0.5).astype(dtype),
        "decay_w0": jnp.asarray(
            jax.random.uniform(ks[4], (d,), jnp.float32, -8.0, -4.0), dtype=jnp.float32
        ),
        "decay_w1": (jax.random.normal(ks[5], (d, r.decay_lora), jnp.float32) * 0.02).astype(dtype),
        "decay_w2": (jax.random.normal(ks[6], (r.decay_lora, d), jnp.float32) * 0.02).astype(dtype),
        "bonus": (jax.random.normal(ks[7], (n_heads, r.head_dim), jnp.float32) * 0.02).astype(jnp.float32),
        "wr": linear_init(ks[8], d, d, dtype),
        "wk": linear_init(ks[9], d, d, dtype),
        "wv": linear_init(ks[10], d, d, dtype),
        "wg": linear_init(ks[11], d, d, dtype),
        "wo": linear_init(ks[12], d, d, dtype),
        "ln_scale": jnp.ones((d,), dtype),
        "ln_bias": jnp.zeros((d,), dtype),
    }
    return p


def rwkv_channel_mix_init(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mu_r": (jax.random.uniform(ks[1], (d,)) * 0.5).astype(dtype),
        "wk": linear_init(ks[0], d, cfg.d_ff, dtype),
        "wv": linear_init(ks[1], cfg.d_ff, d, dtype),
        "wr": linear_init(ks[2], d, d, dtype),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    r = cfg.rwkv
    d = cfg.d_model
    n_heads = d // r.head_dim
    return {
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
        "state": jnp.zeros((batch, n_heads, r.head_dim, r.head_dim), jnp.float32),
    }


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x_prev per position; position 0 uses `prev` (cache) or zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(params, x: Array, x_prev: Array):
    """Data-dependent lerp producing the five mixed inputs (w,k,v,r,g)."""
    xx = x_prev - x
    xxx = x + xx * params["mu_x"]
    # (B, S, 5*mix_lora) -> (5, B, S, mix_lora)
    lora = jnp.tanh(jnp.matmul(xxx, params["mix_w1"]))
    lora = lora.reshape(*x.shape[:-1], 5, -1)
    lora = jnp.moveaxis(lora, -2, 0)
    dyn = jnp.einsum("nbsl,nld->nbsd", lora, params["mix_w2"])
    mixed = x[None] + xx[None] * (params["mu"][:, None, None, :] + dyn)
    return {name: mixed[i] for i, name in enumerate(MIX_NAMES)}


def _group_norm(x: Array, scale: Array, bias: Array, n_heads: int, eps=1e-5) -> Array:
    """Per-head layernorm over the concatenated head outputs."""
    b, s, d = x.shape
    xh = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mean = jnp.mean(xh, -1, keepdims=True)
    var = jnp.var(xh, -1, keepdims=True)
    y = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (y.reshape(b, s, d) * scale + bias).astype(x.dtype)


def rwkv_time_mix(
    params: Mapping[str, Any],
    x: Array,
    cfg: ModelConfig,
    mode: str = "causal",
    cache: Optional[Dict] = None,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
) -> Tuple[Array, Optional[Dict]]:
    r = cfg.rwkv
    d = cfg.d_model
    hd = r.head_dim
    n_heads = d // hd
    b, s, _ = x.shape

    prev = cache["shift_t"] if (cache is not None and mode == "decode") else None
    x_prev = _token_shift(x, prev)
    mixed = _ddlerp(params, x, x_prev)
    if taps is not None:
        for nm in MIX_NAMES:
            taps[f"{tap_prefix}.{nm}_in"] = mixed[nm]

    rv = linear(params["wr"], mixed["r"]).reshape(b, s, n_heads, hd)
    kv = linear(params["wk"], mixed["k"]).reshape(b, s, n_heads, hd)
    vv = linear(params["wv"], mixed["v"]).reshape(b, s, n_heads, hd)
    g = linear(params["wg"], mixed["g"])
    decay = params["decay_w0"] + jnp.matmul(
        jnp.tanh(jnp.matmul(mixed["w"], params["decay_w1"])), params["decay_w2"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, s, n_heads, hd)  # in (0, 1)
    u = params["bonus"]  # (H, hd)

    state0 = (
        cache["state"]
        if cache is not None
        else jnp.zeros((b, n_heads, hd, hd), jnp.float32)
    )

    rf = rv.astype(jnp.float32)
    kf = kv.astype(jnp.float32)
    vf = vv.astype(jnp.float32)

    if mode == "decode":
        kv_outer = kf[:, 0, :, :, None] * vf[:, 0, :, None, :]  # (B,H,hd,hd)
        out = jnp.einsum(
            "bhk,bhkv->bhv", rf[:, 0], state0 + u[None, :, :, None] * kv_outer
        )
        state = w[:, 0, :, :, None] * state0 + kv_outer
        y = out[:, None].reshape(b, 1, d)
        new_cache = {"shift_t": x[:, -1], "state": state}
    else:

        def step(st, inp):
            r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
            kv_outer = k_t[..., :, None] * v_t[..., None, :]
            out_t = jnp.einsum(
                "bhk,bhkv->bhv", r_t, st + u[None, :, :, None] * kv_outer
            )
            st = w_t[..., :, None] * st + kv_outer
            return st, out_t

        xs = tuple(
            jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w.astype(jnp.float32))
        )
        state, outs = jax.lax.scan(step, state0, xs)
        y = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
        new_cache = {"shift_t": x[:, -1], "state": state} if cache is not None else None

    y = _group_norm(y.astype(x.dtype), params["ln_scale"], params["ln_bias"], n_heads)
    y = y * jax.nn.silu(g)
    if taps is not None:
        taps[f"{tap_prefix}.out_in"] = y
    return linear(params["wo"], y), new_cache


def rwkv_channel_mix(
    params: Mapping[str, Any],
    x: Array,
    cfg: ModelConfig,
    mode: str = "causal",
    cache: Optional[Dict] = None,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
) -> Tuple[Array, Optional[Dict]]:
    prev = cache["shift_c"] if (cache is not None and mode == "decode") else None
    x_prev = _token_shift(x, prev)
    xx = x_prev - x
    xk = x + xx * params["mu_k"]
    xr = x + xx * params["mu_r"]
    if taps is not None:
        taps[f"{tap_prefix}.k_in"] = xk
        taps[f"{tap_prefix}.r_in"] = xr
    h = jnp.square(jax.nn.relu(linear(params["wk"], xk)))
    if taps is not None:
        taps[f"{tap_prefix}.mid"] = h
    v = linear(params["wv"], h)
    y = jax.nn.sigmoid(linear(params["wr"], xr)) * v
    new_cache = {"shift_c": x[:, -1]} if cache is not None else None
    return y, new_cache
