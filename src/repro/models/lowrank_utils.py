"""Small helpers shared by model layers for factored params."""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp


def dense_kernel(params: Mapping[str, Any]) -> jax.Array:
    """Materialize the (in, out) kernel from dense or factored params.

    Used where a weight participates in a non-matmul construction (e.g. MLA
    absorption).  The materialized matrix is rank-width small in the MLA case
    (kv_lora_rank rows), so this stays cheap.
    """
    if "kernel" in params:
        return params["kernel"]
    k = jnp.matmul(params["u"], params["v"])
    if "u2" in params:
        k = k + jnp.matmul(params["u2"], params["v2"])
    return k
