"""Block assembly + scan-over-layers stacking.

A decoder layer is (mixer, ffn) with pre-norm residuals:

    x = x + mixer(norm(x))          mixer in {gqa, mla, mamba, rwkv}
    [x = x + cross_attn(norm(x))]   (enc-dec decoder only)
    x = x + ffn(norm(x))            ffn in {mlp, moe, cmix}

Layers with identical specs are *stacked* (params get a leading dim) and run
under ``jax.lax.scan`` — keeping HLO size O(distinct layer kinds), which is
what makes compiling 61-layer deepseek-v3 for 512 SPMD partitions tractable.
``group_layers`` finds a (prefix, period) decomposition so interleaved
patterns (jamba's 1:7 mamba:attn, deepseek-v3's 3 dense + 58 MoE) stay
scannable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NONE_PARALLEL, Parallelism, param_pspecs

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from .layers import mlp_apply, mlp_init, mlp_taps, norm_apply, norm_init

BlockSpec = Tuple[str, str]  # (mixer, ffn)


def resolve_specs(cfg: ModelConfig) -> Tuple[BlockSpec, ...]:
    """Resolve config-level layer specs to concrete (mixer, ffn) pairs."""
    out = []
    for mixer, ffn in cfg.layer_specs():
        if mixer == "attn":
            mixer = "mla" if cfg.attention == "mla" else "gqa"
        if mixer == "rwkv":
            ffn = "cmix"
        out.append((mixer, ffn))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class StackGroup:
    period: Tuple[BlockSpec, ...]
    repeats: int
    first_layer: int

    @property
    def num_layers(self) -> int:
        return len(self.period) * self.repeats


def group_layers(specs: Sequence[BlockSpec], max_prefix: int = 8) -> List[StackGroup]:
    """Decompose layer specs into [prefix runs] + [periodic scan group]."""
    n = len(specs)
    best = None  # (cost, prefix, q)
    for prefix in range(0, min(max_prefix, n) + 1):
        rem = n - prefix
        if rem == 0:
            cand = (prefix, prefix, 0)
        else:
            q = None
            for qq in range(1, rem + 1):
                if rem % qq == 0 and all(
                    specs[prefix + i] == specs[prefix + (i % qq)] for i in range(rem)
                ):
                    q = qq
                    break
            cand = (prefix + q, prefix, q)
        if best is None or cand[0] < best[0]:
            best = cand
    _, prefix, q = best
    groups: List[StackGroup] = []
    # Prefix: runs of identical specs.
    i = 0
    while i < prefix:
        j = i
        while j < prefix and specs[j] == specs[i]:
            j += 1
        groups.append(StackGroup((specs[i],), j - i, i))
        i = j
    if q:
        groups.append(StackGroup(tuple(specs[prefix : prefix + q]), (n - prefix) // q, prefix))
    return groups


# ------------------------------------------------------------- single block

def block_init(key, spec: BlockSpec, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    mixer, ffn = spec
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.norm, cfg.d_model, dtype)}
    if mixer == "gqa":
        p["attn"] = attn_mod.attention_init(ks[0], cfg, dtype)
    elif mixer == "mla":
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    elif mixer == "rwkv":
        p["rwkv_t"] = rwkv_mod.rwkv_time_mix_init(ks[0], cfg, dtype)
    else:
        raise ValueError(mixer)
    if cross:
        p["norm_cross"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attn_mod.attention_init(ks[2], cfg, dtype)
    p["norm2"] = norm_init(cfg.norm, cfg.d_model, dtype)
    if ffn == "mlp":
        p["mlp"] = mlp_init(ks[1], cfg.activation, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    elif ffn == "cmix":
        p["rwkv_c"] = rwkv_mod.rwkv_channel_mix_init(ks[1], cfg, dtype)
    else:
        raise ValueError(ffn)
    return p


def block_cache_init(
    spec: BlockSpec, cfg: ModelConfig, batch: int, max_len: int, dtype,
    cross: bool, kv_quant: bool = False,
) -> Dict:
    mixer, _ = spec
    c: Dict[str, Any] = {}
    if mixer == "gqa":
        c["attn"] = attn_mod.init_kv_cache(cfg, batch, max_len, dtype, quant=kv_quant)
    elif mixer == "mla":
        c["attn"] = mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
    elif mixer == "mamba":
        c["mamba"] = mamba_mod.init_mamba_cache(cfg, batch, dtype)
    elif mixer == "rwkv":
        c["rwkv"] = rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
    if cross:
        c["cross"] = attn_mod.init_kv_cache(cfg, batch, cfg.encoder_seq, dtype)
    return c


def block_paged_cache_init(
    spec: BlockSpec, cfg: ModelConfig, num_blocks: int, block_size: int,
    dtype, kv_quant: bool = False,
) -> Dict:
    mixer, _ = spec
    if mixer != "gqa":
        raise ValueError(
            f"paged KV cache requires attention (gqa) layers, got {mixer!r}; "
            "see models.api.cache_layout"
        )
    return {
        "attn": attn_mod.init_paged_kv_cache(
            cfg, num_blocks, block_size, dtype, quant=kv_quant
        )
    }


def group_paged_cache_init(
    group: StackGroup, cfg: ModelConfig, num_blocks: int, block_size: int,
    dtype, kv_quant: bool = False,
) -> Dict:
    c = {
        f"sub{j}": block_paged_cache_init(
            spec, cfg, num_blocks, block_size, dtype, kv_quant
        )
        for j, spec in enumerate(group.period)
    }
    if group.repeats == 1:
        return c
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (group.repeats, *x.shape)), c
    )


def _moe_ffn(params_moe, h, cfg, par: Parallelism, taps, tp):
    """Dispatch MoE densely (single device) or via the EP shard_map."""
    if not par.active:
        return moe_mod.moe_apply(params_moe, h, cfg, ep_axis=None, taps=taps, tap_prefix=tp)
    assert taps is None, "taps unsupported under expert-parallel shard_map"
    from jax.sharding import PartitionSpec as P

    moe_in_specs = param_pspecs(jax.tree.map(lambda x: x, params_moe))
    # batch=1 long-context cells can't shard batch over DP — replicate
    # (each data shard redundantly routes the single row; EP still splits
    # the expert compute over the model axis).
    dp_size = 1
    for a in par.dp_axes:
        dp_size *= par.mesh.shape[a]
    x_spec = P(par.dp, None, None) if h.shape[0] % dp_size == 0 else P(None, None, None)

    def inner(p, xx):
        out, aux = moe_mod.moe_apply(p, xx, cfg, ep_axis=par.tp_axis)
        aux = jax.lax.pmean(aux, par.dp_axes)
        return out, aux

    out, aux = jax.shard_map(
        inner,
        mesh=par.mesh,
        in_specs=(moe_in_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params_moe, h)
    return out, aux


def block_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    spec: BlockSpec,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mode: str,
    cache: Optional[Dict] = None,
    cache_len: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    par: Parallelism = NONE_PARALLEL,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
    encoder: bool = False,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    mixer, ffn = spec
    if block_tables is not None and mixer != "gqa":
        raise ValueError(f"paged decode unsupported for mixer {mixer!r}")
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    h = norm_apply(params["norm1"], x)
    if mixer == "gqa":
        attn_mode = "bidir" if encoder else ("decode" if mode == "decode" else "causal")
        y, c = attn_mod.attention_apply(
            params["attn"], h, cfg, positions,
            mode=attn_mode,
            cache=None if cache is None else cache.get("attn"),
            cache_len=cache_len,
            taps=taps, tap_prefix=f"{tap_prefix}.attn",
            block_tables=block_tables,
        )
        if c is not None:
            new_cache["attn"] = c
    elif mixer == "mla":
        y, c = mla_mod.mla_apply(
            params["attn"], h, cfg, positions,
            mode="decode" if mode == "decode" else "causal",
            cache=None if cache is None else cache.get("attn"),
            cache_len=cache_len,
            taps=taps, tap_prefix=f"{tap_prefix}.attn",
        )
        if c is not None:
            new_cache["attn"] = c
    elif mixer == "mamba":
        y, c = mamba_mod.mamba_apply(
            params["mamba"], h, cfg,
            mode="decode" if mode == "decode" else "causal",
            cache=None if cache is None else cache.get("mamba"),
            taps=taps, tap_prefix=f"{tap_prefix}.mamba",
        )
        if c is not None:
            new_cache["mamba"] = c
    elif mixer == "rwkv":
        y, c = rwkv_mod.rwkv_time_mix(
            params["rwkv_t"], h, cfg,
            mode="decode" if mode == "decode" else "causal",
            cache=None if cache is None else cache.get("rwkv"),
            taps=taps, tap_prefix=f"{tap_prefix}.rwkv_t",
        )
        if c is not None:
            new_cache["rwkv"] = dict(c)
    else:
        raise ValueError(mixer)
    x = x + y

    if "cross" in params:
        h = norm_apply(params["norm_cross"], x)
        if mode == "decode":
            # Cached cross K/V (computed at prefill) — attend directly.
            y, _ = _cross_cached(params["cross"], h, cfg, cache["cross"])
            # Pass the (donated) cross cache through so the cache pytree
            # keeps its structure across decode steps.
            new_cache["cross"] = cache["cross"]
        else:
            y, ckv = attn_mod.attention_apply(
                params["cross"], h, cfg, positions, mode="cross", memory=memory,
                taps=taps, tap_prefix=f"{tap_prefix}.cross",
            )
            if cache is not None:
                new_cache["cross"] = _build_cross_cache(params["cross"], memory, cfg)
        x = x + y

    h = norm_apply(params["norm2"], x)
    if ffn == "mlp":
        if taps is not None:
            y = mlp_taps(params["mlp"], h, cfg.activation, taps, f"{tap_prefix}.mlp")
        else:
            y = mlp_apply(params["mlp"], h, cfg.activation)
    elif ffn == "moe":
        y, aux = _moe_ffn(params["moe"], h, cfg, par, taps, f"{tap_prefix}.moe")
    elif ffn == "cmix":
        y, c = rwkv_mod.rwkv_channel_mix(
            params["rwkv_c"], h, cfg,
            mode="decode" if mode == "decode" else "causal",
            cache=None if cache is None else cache.get("rwkv"),
            taps=taps, tap_prefix=f"{tap_prefix}.rwkv_c",
        )
        if c is not None:
            new_cache.setdefault("rwkv", {}).update(c)
    else:
        raise ValueError(ffn)
    x = x + y
    return x, (new_cache if new_cache else None), aux


def _build_cross_cache(params, memory, cfg: ModelConfig) -> Dict:
    """Precompute cross-attention K/V from encoder memory (decode reuse)."""
    from .attention import _split_heads
    from .layers import linear

    k = _split_heads(linear(params["wk"], memory), cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(linear(params["wv"], memory), cfg.num_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}


def _cross_cached(params, x, cfg: ModelConfig, cross_cache):
    """Decode-time cross-attention against the prefilled K/V."""
    import math

    from .attention import _gqa_out, _gqa_scores, _split_heads
    from .layers import linear

    q = _split_heads(linear(params["wq"], x), cfg.num_heads, cfg.head_dim)
    k, v = cross_cache["k"], cross_cache["v"]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, scale)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v)
    y = linear(params["wo"], out.reshape(*x.shape[:-1], -1))
    return y, None


# ------------------------------------------------------------ stacked groups

def group_init(key, group: StackGroup, cfg: ModelConfig, dtype, cross: bool) -> Dict:
    """Stacked params: {"sub{j}": stacked block params} with leading repeats."""

    def one(k):
        ks = jax.random.split(k, len(group.period))
        return {
            f"sub{j}": block_init(ks[j], spec, cfg, dtype, cross)
            for j, spec in enumerate(group.period)
        }

    if group.repeats == 1:
        return one(key)
    keys = jax.random.split(key, group.repeats)
    return jax.vmap(one)(keys)


def group_cache_init(
    group: StackGroup, cfg: ModelConfig, batch: int, max_len: int, dtype,
    cross: bool, kv_quant: bool = False,
) -> Dict:
    def one():
        return {
            f"sub{j}": block_cache_init(spec, cfg, batch, max_len, dtype, cross,
                                        kv_quant)
            for j, spec in enumerate(group.period)
        }

    c = one()
    if group.repeats == 1:
        return c
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (group.repeats, *x.shape)), c
    )


def group_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    group: StackGroup,
    cfg: ModelConfig,
    *,
    positions,
    mode: str,
    cache=None,
    cache_len=None,
    memory=None,
    par: Parallelism = NONE_PARALLEL,
    taps: Optional[Dict] = None,
    tap_group: str = "",
    encoder: bool = False,
    remat: bool = False,
    unroll: bool = False,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Apply a stack group; scans when repeats > 1.  ``unroll=True`` fully
    unrolls the layer scan (roofline mode: exact HLO flop accounting —
    cost_analysis counts a while body once; see launch/roofline.py)."""

    def apply_period(p, xx, cc, layer_tag: Optional[str]):
        new_caches = {}
        aux_total = jnp.zeros((), jnp.float32)
        for j, spec in enumerate(group.period):
            tp = f"{tap_group}/{layer_tag}/sub{j}" if layer_tag is not None else f"{tap_group}/sub{j}"
            xx, nc, aux = block_apply(
                p[f"sub{j}"], xx, spec, cfg,
                positions=positions, mode=mode,
                cache=None if cc is None else cc.get(f"sub{j}"),
                cache_len=cache_len, memory=memory, par=par,
                taps=taps, tap_prefix=tp, encoder=encoder,
                block_tables=block_tables,
            )
            if nc is not None:
                new_caches[f"sub{j}"] = nc
            aux_total = aux_total + aux
        return xx, (new_caches if new_caches else None), aux_total

    if group.repeats == 1:
        return apply_period(params, x, cache, None)

    if taps is not None:
        # Calibration path: unroll so per-layer taps stay addressable.
        new_cache_list = []
        aux_total = jnp.zeros((), jnp.float32)
        for r in range(group.repeats):
            p_r = jax.tree.map(lambda t: t[r], params)
            c_r = None if cache is None else jax.tree.map(lambda t: t[r], cache)
            x, nc, aux = apply_period(p_r, x, c_r, f"rep{r}")
            aux_total = aux_total + aux
            new_cache_list.append(nc)
        new_cache = None
        if new_cache_list[0] is not None:
            new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *new_cache_list)
        return x, new_cache, aux_total

    def body(carry, xs):
        xx = carry
        p, cc = xs
        xx, nc, aux = apply_period(p, xx, cc, None)
        return xx, (nc, aux)

    if remat:
        body = jax.checkpoint(body)

    x, (new_cache, auxs) = jax.lax.scan(
        body, x, (params, cache), unroll=group.repeats if unroll else 1
    )
    return x, new_cache, jnp.sum(auxs)
