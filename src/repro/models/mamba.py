"""Mamba (S6) selective-state-space mixer — jamba's sequence layer.

TPU adaptation (DESIGN.md §3): the CUDA reference fuses the recurrence into
a warp-level scan; here the diagonal-A recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,     y_t = C_t . h_t + D x_t

is chunked: a sequential lax.scan over chunks carries the (B, Di, N) state,
and within a chunk the recurrence runs as an associative scan
(work-efficient, parallel over the chunk) — bounding the materialized
(chunk, Di, N) tensor to VMEM-friendly sizes.

Decode: single-step state update against the {"h", "conv"} cache.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import linear, linear_init

Array = jax.Array


def mamba_init(key, cfg: ModelConfig, dtype) -> Dict:
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner
    ns = mc.d_state
    dt_rank = mc.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a_init = jnp.tile(jnp.arange(1, ns + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": linear_init(ks[0], d, 2 * di, dtype),
        "conv": {
            "w": (jax.random.normal(ks[1], (mc.d_conv, di), jnp.float32) * 0.1).astype(dtype),
            "b": jnp.zeros((di,), dtype),
        },
        "x_proj": linear_init(ks[2], di, dt_rank + 2 * ns, dtype),
        "dt_proj": {
            "kernel": (jax.random.normal(ks[3], (dt_rank, di), jnp.float32) * (dt_rank ** -0.5)).astype(dtype),
            "bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        },
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(ks[4], di, d, dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    mc = cfg.mamba
    return {
        "h": jnp.zeros((batch, mc.d_inner, mc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, mc.d_inner), dtype),
    }


def _causal_depthwise_conv(x: Array, w: Array, b: Array, tail: Optional[Array]) -> Array:
    """x: (B, S, Di); w: (K, Di).  Causal: pads with `tail` (or zeros)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    return out + b


def _ssm_params(params, xc: Array, cfg: ModelConfig, taps=None, tap_prefix=""):
    mc = cfg.mamba
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    if taps is not None:
        taps[f"{tap_prefix}.ssm_in"] = xc
    proj = linear(params["x_proj"], xc)
    dt_in = proj[..., :dt_rank]
    if taps is not None:
        taps[f"{tap_prefix}.dt_in"] = dt_in
    b_mat = proj[..., dt_rank : dt_rank + mc.d_state]
    c_mat = proj[..., dt_rank + mc.d_state :]
    dt = jnp.matmul(dt_in, params["dt_proj"]["kernel"]) + params["dt_proj"]["bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (B, S, Di)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (Di, N)
    return dt, a, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32)


def _chunk_scan(dt, a, b_mat, c_mat, xc, h0, chunk: int):
    """Chunked associative scan of the diagonal SSM recurrence.

    dt: (B, S, Di), a: (Di, N), b_mat/c_mat: (B, S, N), xc: (B, S, Di)
    h0: (B, Di, N) initial state.  Returns (y (B, S, Di), h_final).

    The (.., Di, N) decay / input-outer tensors are formed INSIDE the chunk
    body from (B, chunk, ..) slices, so only one chunk's (B, chunk, Di, N)
    tensor is ever live — materializing them over the full sequence first
    costs nchunks x the memory for zero benefit (EXPERIMENTS.md §Perf,
    jamba iteration 1: 16x reduction of the dominant temp allocation).
    """
    bsz, s, di = dt.shape
    n = a.shape[1]
    nchunks = max(1, s // chunk)
    chunk = s // nchunks

    dt_c = jnp.moveaxis(dt.reshape(bsz, nchunks, chunk, di), 1, 0)
    bx_c = jnp.moveaxis(
        (dt * xc.astype(jnp.float32)).reshape(bsz, nchunks, chunk, di), 1, 0
    )
    b_c = jnp.moveaxis(b_mat.reshape(bsz, nchunks, chunk, n), 1, 0)
    c_c = jnp.moveaxis(c_mat.reshape(bsz, nchunks, chunk, n), 1, 0)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b2 + a2 * b1

    def step(h, inputs):
        dt_k, bx_k, b_k, c_k = inputs  # (B, chunk, Di) / (B, chunk, N)
        da_k = jnp.exp(dt_k[..., None] * a)  # (B, chunk, Di, N)
        dbx_k = bx_k[..., None] * b_k[:, :, None, :]
        # Prefix products within the chunk (parallel).
        acc_a, acc_b = jax.lax.associative_scan(assoc, (da_k, dbx_k), axis=1)
        h_t = acc_a * h[:, None] + acc_b  # (B, chunk, Di, N)
        y_c = jnp.einsum("bsdn,bsn->bsd", h_t, c_k)
        return h_t[:, -1], y_c

    h_final, y = jax.lax.scan(step, h0, (dt_c, bx_c, b_c, c_c))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, s, di)
    return y, h_final


def mamba_apply(
    params: Mapping[str, Any],
    x: Array,
    cfg: ModelConfig,
    mode: str = "causal",
    cache: Optional[Dict] = None,
    chunk: int = 256,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
) -> Tuple[Array, Optional[Dict]]:
    mc = cfg.mamba
    b, s, _ = x.shape
    if taps is not None:
        taps[f"{tap_prefix}.in"] = x
    xz = linear(params["in_proj"], x)
    xpart, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if mode == "decode":
        assert cache is not None and s == 1
        tail = cache["conv"]
        xc = _causal_depthwise_conv(xpart, params["conv"]["w"], params["conv"]["b"], tail)
        new_tail = jnp.concatenate([tail[:, 1:], xpart], axis=1)
        xc = jax.nn.silu(xc)
        dt, a, b_mat, c_mat = _ssm_params(params, xc, cfg, taps, tap_prefix)
        da = jnp.exp(dt[:, 0, :, None] * a)  # (B, Di, N)
        dbx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_mat[:, 0, None, :]
        h = da * cache["h"] + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None, :]
        new_cache = {"h": h, "conv": new_tail}
    else:
        xc = _causal_depthwise_conv(xpart, params["conv"]["w"], params["conv"]["b"], None)
        xc = jax.nn.silu(xc)
        dt, a, b_mat, c_mat = _ssm_params(params, xc, cfg, taps, tap_prefix)
        h0 = jnp.zeros((b, mc.d_inner, mc.d_state), jnp.float32)
        y, h_final = _chunk_scan(dt, a, b_mat, c_mat, xc, h0, chunk)
        if cache is not None:
            k = mc.d_conv - 1
            new_cache = {"h": h_final, "conv": xpart[:, -k:, :]}

    y = y.astype(x.dtype) + params["d_skip"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    if taps is not None:
        taps[f"{tap_prefix}.out_in"] = y
    return linear(params["out_proj"], y), new_cache
