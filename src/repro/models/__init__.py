from .api import (
    Model,
    build_model,
    cache_layout,
    cache_specs,
    count_active_params,
    count_params,
    input_specs,
    param_specs,
)
from .encdec import EncDecLM
from .transformer import DecoderLM
