"""Loss functions: next-token cross-entropy with optional logit chunking.

``chunked`` mode never materializes the full (B, S, V) logits — it scans
over sequence chunks computing per-chunk logsumexp + target logit.  For the
163k-vocab archs this cuts the dominant train-step memory term ~8x
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def next_token_xent(
    logits: jax.Array, tokens: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """logits (B, S, V) predicting tokens shifted by one; mean nats/token."""
    targets = tokens[:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - tgt
    if mask is None:
        return jnp.mean(nll)
    m = mask[:, 1:].astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def chunked_xent_from_hidden(
    hidden: jax.Array,
    unembed_params,
    tokens: jax.Array,
    chunk: int = 512,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Cross-entropy computed from final hidden states in sequence chunks.

    hidden: (B, S, D) final (post-norm) states; unembed_params: {"kernel"} or
    tied {"table"}.  Avoids the (B, S, V) logits tensor entirely.
    """
    b, s, d = hidden.shape
    h = hidden[:, :-1]
    targets = tokens[:, 1:]
    m = None if mask is None else mask[:, 1:].astype(jnp.float32)
    n = s - 1
    nchunks = max(1, -(-n // chunk))
    pad = nchunks * chunk - n
    h = jnp.pad(h, [(0, 0), (0, pad), (0, 0)])
    targets = jnp.pad(targets, [(0, 0), (0, pad)])
    mm = jnp.pad(
        jnp.ones((b, n), jnp.float32) if m is None else m, [(0, 0), (0, pad)]
    )
    h = h.reshape(b, nchunks, chunk, d)
    targets = targets.reshape(b, nchunks, chunk)
    mm = mm.reshape(b, nchunks, chunk)

    if "table" in unembed_params:
        w = unembed_params["table"].T  # (D, V)
    else:
        from repro.core.lowrank import dense_equivalent

        w = dense_equivalent(unembed_params)

    def step(carry, idx):
        tot, cnt = carry
        hc = h[:, idx]
        logits = jnp.matmul(hc, w).astype(jnp.float32)  # (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[:, idx][..., None], -1)[..., 0]
        nll = (logz - tgt) * mm[:, idx]
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm[:, idx])), None

    (tot, cnt), _ = jax.lax.scan(step, (0.0, 0.0), jnp.arange(nchunks))
    return tot / jnp.maximum(cnt, 1.0)
