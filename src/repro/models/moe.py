"""Token-choice top-k MoE with capacity, shared experts, and EP dispatch.

Two execution paths share the same dispatch math:

  * single-device (``ep_axis=None``): plain jnp — used by smoke tests,
    calibration and the small-LM repro experiments.  Can emit per-expert
    activation taps for the per-expert Gram extension (DESIGN.md §7).

  * expert-parallel (``ep_axis='model'``): called *inside* a fully-manual
    shard_map.  Because the residual stream is replicated across the model
    axis, each model shard simply gathers the tokens routed to its local
    experts into an (E_local, C, D) capacity buffer — no all-to-all — and
    the combine is a single psum, which XLA overlaps with the next block.
    This is the TPU-native mapping of the paper-era GPU MoE dispatch
    (DESIGN.md §3).

Dispatch: token-slots are sorted by (local) expert id (stable), ranked
within their expert, and dropped beyond capacity
C = ceil(N * top_k * cf / E) (drop-by-position, Switch-style).
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import linear, linear_init

Array = jax.Array


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    e = m.num_experts
    ks = jax.random.split(key, 8)
    std = 1.0 / (d ** 0.5)
    params: Dict[str, Any] = {
        "router": {
            # Router kept fp32 for routing stability.
            "kernel": jax.random.normal(ks[0], (d, e), jnp.float32) * std
        },
        "experts": {
            "wi": {"kernel": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * std).astype(dtype)},
            "wg": {"kernel": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * std).astype(dtype)},
            "wo": {"kernel": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * (1.0 / f ** 0.5)).astype(dtype)},
        },
    }
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        params["shared"] = {
            "wi": linear_init(ks[4], d, fs, dtype),
            "wg": linear_init(ks[5], d, fs, dtype),
            "wo": linear_init(ks[6], fs, d, dtype),
        }
    return params


class Dispatch(NamedTuple):
    buf: Array  # (E_local, C, D) gathered token embeddings
    valid: Array  # (N*k,) slot validity (local expert & under capacity)
    sorted_e: Array  # (N*k,) local expert id per sorted slot (E_local if remote)
    pos: Array  # (N*k,) rank within expert
    sorted_t: Array  # (N*k,) source token index
    sorted_w: Array  # (N*k,) combine weight


def _dispatch(
    x_flat: Array,
    top_w: Array,
    top_i: Array,
    e0,
    e_local: int,
    capacity: int,
) -> Dispatch:
    """Sort-based capacity dispatch for experts [e0, e0 + e_local)."""
    n, k = top_i.shape
    d = x_flat.shape[-1]
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    flat_w = top_w.reshape(-1)
    local = (flat_e >= e0) & (flat_e < e0 + e_local)
    key = jnp.where(local, flat_e - e0, e_local)
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(sorted_e.shape[0]) - first
    valid = (sorted_e < e_local) & (pos < capacity)
    safe_e = jnp.where(valid, sorted_e, 0)
    safe_p = jnp.where(valid, pos, 0)
    buf = jnp.zeros((e_local, capacity, d), x_flat.dtype)
    buf = buf.at[safe_e, safe_p].add(
        jnp.where(valid[:, None], x_flat[sorted_t], 0).astype(x_flat.dtype)
    )
    return Dispatch(buf, valid, sorted_e, pos, sorted_t, sorted_w)


def _combine(h: Array, disp: Dispatch, n: int) -> Array:
    """Gather each slot's expert output, weight it, scatter-add to tokens."""
    d = h.shape[-1]
    safe_e = jnp.where(disp.valid, disp.sorted_e, 0)
    safe_p = jnp.where(disp.valid, disp.pos, 0)
    slot_out = h[safe_e, safe_p]  # (N*k, D)
    slot_out = slot_out * jnp.where(disp.valid, disp.sorted_w, 0.0)[:, None].astype(
        h.dtype
    )
    out = jnp.zeros((n, d), h.dtype)
    return out.at[disp.sorted_t].add(slot_out)


def _expert_ffn(experts: Mapping[str, Any], buf: Array) -> Array:
    """buf: (E, C, D) -> (E, C, D) through each expert's SwiGLU FFN.

    Supports dense (E, D, F) kernels or factored {u: (E, D, k), v: (E, k, F)}
    (+ nested u2/v2) — the MoE twin of lowrank.linear_apply.  Nested factors
    dispatch through ``kernels.nested_lowrank.ops`` vmapped over the expert
    dim (fused Pallas kernel per expert on TPU — the capacity buffer C is
    decode-shaped — jnp oracle elsewhere), matching how dense/attention/MLP
    layers already route.
    """

    def emm(p, hh):
        if "kernel" in p:
            return jnp.einsum("ecd,edf->ecf", hh, p["kernel"])
        if "u2" in p:
            from repro.kernels.nested_lowrank import ops as nlr_ops

            return jax.vmap(nlr_ops.nested_lowrank_matmul)(
                hh, p["u"], p["v"], p["u2"], p["v2"]
            )
        return jnp.einsum(
            "eck,ekf->ecf", jnp.einsum("ecd,edk->eck", hh, p["u"]), p["v"]
        )

    h = jax.nn.silu(emm(experts["wg"], buf)) * emm(experts["wi"], buf)
    return emm(experts["wo"], h), h


def router_probs(params, x: Array) -> Array:
    logits = jnp.matmul(x.astype(jnp.float32), params["router"]["kernel"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def moe_apply(
    params: Mapping[str, Any],
    x: Array,
    cfg: ModelConfig,
    ep_axis: Optional[str] = None,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
) -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux load-balance loss scalar).

    When ``ep_axis`` is set this must run inside a shard_map where the
    expert dim is sharded along that axis and x is replicated along it.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)
    probs = router_probs(params, x_flat)  # (N, E) fp32
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    e = m.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f_e = counts / (n * m.top_k)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    if ep_axis is None:
        e0, e_local = 0, e
    else:
        size = jax.lax.axis_size(ep_axis)
        e_local = e // size
        e0 = jax.lax.axis_index(ep_axis) * e_local

    capacity = max(8, -(-n * m.top_k * int(4 * m.capacity_factor) // (4 * e)))
    disp = _dispatch(x_flat, top_w, top_i, e0, e_local, capacity)

    h, h_mid = _expert_ffn(params["experts"], disp.buf)  # (E_local, C, D/F)
    if taps is not None:
        taps[f"{tap_prefix}.router_in"] = x_flat
        taps[f"{tap_prefix}.expert_buf"] = disp.buf
        taps[f"{tap_prefix}.expert_mid"] = h_mid
    out = _combine(h, disp, n)

    # Shared experts (always-on dense SwiGLU).  Inside the EP shard_map their
    # width arrives pre-sliced along the model axis, so the partial outputs
    # ride the same psum as the routed-expert combine.
    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(linear(sh["wg"], x_flat)) * linear(sh["wi"], x_flat)
        if taps is not None:
            taps[f"{tap_prefix}.shared_in"] = x_flat
            taps[f"{tap_prefix}.shared_mid"] = hs
        out = out + linear(sh["wo"], hs).astype(out.dtype)

    if ep_axis is not None:
        out = jax.lax.psum(out, ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)

    return out.reshape(b, s, d), aux
