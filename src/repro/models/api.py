"""Model facade: build any registered arch, get inputs/steps/targets.

``build_model(cfg, par)`` returns a DecoderLM or EncDecLM; ``input_specs``
produces ShapeDtypeStruct stand-ins for every input of a shape case
(weak-type-correct, shardable, no device allocation) — the dry-run contract.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCase
from repro.parallel.sharding import NONE_PARALLEL, Parallelism

from .encdec import EncDecLM
from .transformer import DecoderLM, VISION_FEATURE_DIM

Model = Union[DecoderLM, EncDecLM]


def build_model(
    cfg: ModelConfig,
    par: Parallelism = NONE_PARALLEL,
    remat: bool = False,
    unroll: bool = False,
    seq_parallel: bool = False,
) -> Model:
    if cfg.is_encdec:
        return EncDecLM(cfg, par, remat, unroll)
    return DecoderLM(cfg, par, remat, unroll, seq_parallel)


def input_specs(cfg: ModelConfig, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the batch of one (arch x shape) cell.

    train/prefill: full (B, S) token batch (+ modality stubs).
    decode: one new token per row; the KV cache itself is part of the step
    *state* (see launch/steps.py), not the batch.
    """
    b, s = case.global_batch, case.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if case.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), f32
            )
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.frontend == "vision":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, VISION_FEATURE_DIM), f32
            )
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if case.kind == "train":
            specs["loss_mask"] = jax.ShapeDtypeStruct(
                specs["tokens"].shape, f32
            )
        return specs
    # decode: one token per row + per-row cache lengths.
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache_len": jax.ShapeDtypeStruct((b,), i32),
    }


def cache_specs(cfg: ModelConfig, case: ShapeCase) -> Any:
    """ShapeDtypeStructs of the KV/state cache for a decode case."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(case.global_batch, case.seq_len)
    )


# Cache leaves holding RECURRENT state (SSM/RWKV): their post-prefill value
# depends on every input position, so right-padding a prompt corrupts them.
# Attention leaves (k/v/...) are per-position and masked by cache_len, so
# padded rows are never attended before being overwritten.
RECURRENT_CACHE_LEAVES = frozenset({"h", "conv", "state", "shift_t", "shift_c"})


def cache_leaf_names(model: Model) -> frozenset:
    """Distinct cache leaf names of a model (no device allocation)."""
    shapes = jax.eval_shape(lambda: model.init_cache(1, 8))
    names = set()

    def walk(tree):
        for k, v in tree.items():
            if isinstance(v, dict):
                walk(v)
            else:
                names.add(k)

    walk(shapes)
    return frozenset(names)


def has_recurrent_cache(model: Model) -> bool:
    """True when the model carries recurrent state in its cache, i.e.
    prompts cannot be right-padded to bucketed prefill lengths."""
    return bool(cache_leaf_names(model) & RECURRENT_CACHE_LEAVES)


# Cache leaves a block-pool (paged) layout can host: per-position attention
# K/V plus their int8 dequant scales.  Anything else (recurrent state, MLA
# latents, cross-attention memory) keeps the dense (max_batch, max_len) slab.
PAGEABLE_CACHE_LEAVES = frozenset({"k", "v", "k_scale", "v_scale"})


def cache_layout(model: Model) -> str:
    """How the serving engine should lay out this model's decode cache.

    "paged": every cache leaf is per-position attention K/V (pure-GQA
    stacks), so the engine may use the block-table pool from
    ``serving/kvcache`` with per-request max_len and chunked prefill.

    "dense": one (max_batch, max_len) slab per leaf.  Recurrent caches
    (SSM/RWKV) and token-choice MoE keep this path — the same families that
    are pad-sensitive at prefill — as do MLA latents and enc-dec cross
    caches, whose leaves are not plain paged K/V."""
    if model.cfg.is_encdec:
        return "dense"
    if not prefill_pad_safe(model):
        return "dense"
    if not cache_leaf_names(model) <= PAGEABLE_CACHE_LEAVES:
        return "dense"
    return "paged"


# ------------------------------------------------- serving cache sharding
#
# The serving engine's mesh story (launch/steps.ServingShardings): weights
# are TP-sharded over "model" via param_pspecs, while the decode cache is
# DATA-parallel — the dense slab shards over its batch (slot) dim, paged
# block pools over their block dim — and replicates over TP.  The functions
# below find the right dim STRUCTURALLY (scanned layer stacks carry leading
# repeat dims, so the axis is not fixed per leaf — and shape sniffing would
# misfire when repeats equals the probed size) and emit the cache_layout-
# aware PartitionSpec tree the engine plugs into its jit roots.


def _grown_axes(tree_a: Any, tree_b: Any) -> Any:
    """Per leaf: the single dim index whose size differs between the two
    shape probes."""
    return jax.tree.map(
        lambda a, b: next(
            i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y
        ),
        tree_a, tree_b,
    )


def paged_cache_block_axes(model: Model, num_blocks: int, block_size: int,
                           kv_quant: bool = False) -> Any:
    """Per-leaf block axis of the paged pools (eval_shape probe: grow
    num_blocks by one and see which dim moved)."""
    a = jax.eval_shape(lambda: model.init_paged_cache(
        num_blocks, block_size, kv_quant=kv_quant))
    b = jax.eval_shape(lambda: model.init_paged_cache(
        num_blocks + 1, block_size, kv_quant=kv_quant))
    return _grown_axes(a, b)


def dense_cache_batch_axes(model: Model, max_batch: int, max_len: int,
                           kv_quant: bool = False) -> Any:
    """Per-leaf batch (slot) axis of the dense serving slab."""
    a = jax.eval_shape(lambda: model.init_cache(
        max_batch, max_len, kv_quant=kv_quant))
    b = jax.eval_shape(lambda: model.init_cache(
        max_batch + 1, max_len, kv_quant=kv_quant))
    return _grown_axes(a, b)


def serving_cache_pspecs(model: Model, par: Parallelism, *,
                         max_batch: Optional[int] = None,
                         max_len: Optional[int] = None,
                         num_blocks: Optional[int] = None,
                         block_size: Optional[int] = None,
                         kv_quant: bool = False,
                         axes: Any = None, shapes: Any = None) -> Any:
    """cache_layout-aware PartitionSpec tree for the serving decode cache.

    Pass (num_blocks, block_size) for the paged layout — block dim sharded
    over the DP axes — or (max_batch, max_len) for the dense slab — batch
    dim sharded over the DP axes.  Dims not divisible by the DP size stay
    replicated (jit boundaries require exact divisibility), as does
    everything on the TP axis: the cache is pure data-parallel state.

    ``axes``/``shapes``: optional precomputed axis tree + cache (shape)
    tree — callers that already probed (PagedKVCache keeps its block axes)
    pass them to skip re-tracing the cache init."""
    import numpy as _np
    from jax.sharding import PartitionSpec as _P

    if (num_blocks is None) == (max_batch is None):
        raise ValueError(
            "pass exactly one of num_blocks (paged) or max_batch (dense)"
        )
    if axes is None:
        axes = (paged_cache_block_axes(model, num_blocks, block_size,
                                       kv_quant=kv_quant)
                if num_blocks is not None else
                dense_cache_batch_axes(model, max_batch, max_len,
                                       kv_quant=kv_quant))
    if shapes is None:
        shapes = jax.eval_shape(
            (lambda: model.init_paged_cache(num_blocks, block_size,
                                            kv_quant=kv_quant))
            if num_blocks is not None else
            (lambda: model.init_cache(max_batch, max_len,
                                      kv_quant=kv_quant)))
    dp_size = 1
    if par.mesh is not None:
        dp_size = int(_np.prod([par.mesh.shape[a] for a in par.dp_axes]))

    def spec(leaf, ax):
        entries = [None] * len(leaf.shape)
        if par.mesh is not None and leaf.shape[ax] % dp_size == 0:
            entries[ax] = par.dp
        return _P(*entries)

    return jax.tree.map(spec, shapes, axes)


def prefill_pad_safe(model: Model) -> bool:
    """True when right-padding a prompt cannot change real positions'
    outputs, i.e. the serving engine may bucket prompt lengths.

    Two architecture families are pad-SENSITIVE: recurrent caches
    (SSM/RWKV state folds in every input position) and token-choice MoE
    (expert capacity is budgeted over the flattened token batch, so padding
    tokens compete for — and can evict real tokens from — expert slots).
    """
    if has_recurrent_cache(model):
        return False
    return getattr(model.cfg, "moe", None) is None


def build_draft_params(model: Model, params: Any, grams: Any, ratio: float,
                       method: str = "nsvd1") -> Any:
    """Draft construction from a compression plan: factor ``params`` at a
    HIGHER compression ratio than the serving target, yielding the
    self-speculative draft checkpoint (same architecture, cheaper matmuls
    — the factored leaves dispatch through ``linear_apply`` unchanged).

    NSVD is training-free, so the draft costs one extra ``build_plan`` +
    ``compress_params`` pass over the same calibration Grams the target's
    compression already collected — the compression sweep ships its own
    draft models for free.  Pass the result as
    ``SpecConfig(draft_params=...)`` (serving/spec)."""
    from repro.core import CompressionConfig, build_plan, compress_params

    if not 0.0 < ratio < 1.0:
        raise ValueError(f"draft compression ratio must be in (0, 1), got {ratio}")
    plan = build_plan(
        model.compressible_targets(),
        CompressionConfig(method=method, ratio=ratio, dtype="float32",
                          use_randomized=False),
    )
    return compress_params(params, plan, grams)


def param_specs(cfg: ModelConfig, seed: int = 0) -> Any:
    """ShapeDtypeStructs of the model params (no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(seed)))


def count_params(cfg: ModelConfig) -> int:
    import numpy as np

    shapes = param_specs(cfg)
    return int(
        sum(np.prod(x.shape) for x in jax.tree.leaves(shapes))
    )


def count_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k + shared experts count)."""
    import numpy as np

    total = count_params(cfg)
    if cfg.moe is None:
        return total
    # Subtract inactive expert params.
    m = cfg.moe
    n_moe_layers = sum(1 for _, f in _specs(cfg) if f == "moe")
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return int(total - inactive)


def _specs(cfg: ModelConfig):
    from .blocks import resolve_specs

    return resolve_specs(cfg)
