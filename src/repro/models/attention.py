"""GQA/MHA attention with KV cache, flash-style chunked prefill, cross-attn.

Conventions:
  x:          (B, S, D)
  positions:  (B, S) int32 absolute positions
  cache:      {"k": (B, S_max, Hkv, hd), "v": (B, S_max, Hkv, hd)}
  cache_len:  (B,) int32 — tokens already in the cache (per row; supports
              continuous batching with ragged fill)

Modes:
  train/prefill: full causal pass, optionally writing the cache
  decode:        q from one new token per row, attends over the cache
  bidir:         encoder self-attention (no mask)
  cross:         decoder cross-attention over precomputed memory K/V
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import apply_rope, linear, linear_init, rope_frequencies

NEG_INF = -1e30


def attention_init(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    hq = cfg.num_heads * cfg.head_dim
    hkv = cfg.num_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_init(ks[0], d, hq, dtype),
        "wk": linear_init(ks[1], d, hkv, dtype),
        "wv": linear_init(ks[2], d, hkv, dtype),
        "wo": linear_init(ks[3], hq, d, dtype),
    }


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype, quant: bool = False
) -> Dict:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if quant:
        # int8 symmetric per-(position, head) quantization: halves the
        # decode-dominant cache read bytes (§Perf, deepseek-67b decode).
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float32),
            "v_scale": jnp.zeros(shape[:3], jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(
    cfg: ModelConfig, num_blocks: int, block_size: int, dtype,
    quant: bool = False,
) -> Dict:
    """Block-pool KV cache: K/V for ALL rows share (num_blocks, block_size)
    pages; a block table (owned by the serving engine, passed per call) maps
    each row's logical positions onto physical pages.  HBM footprint scales
    with pool capacity — i.e. live tokens — not (max_batch, max_len)."""
    shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    if quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float32),
            "v_scale": jnp.zeros(shape[:3], jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x: jax.Array):
    """x: (..., hd) -> (int8, scale (...,)) symmetric per-vector."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _split_heads(x: jax.Array, n_heads: int, head_dim: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _merge_heads(x: jax.Array) -> jax.Array:
    return x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """q: (B,S,Hq,hd), k: (B,T,Hkv,hd) -> scores (B,Hkv,G,S,T) fp32."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    return scores * scale


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    b, hkv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hkv * g, v.shape[-1])


def _naive_attention(q, k, v, mask, scale):
    scores = _gqa_scores(q, k, scale)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v)


def chunked_causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, scale: float, chunk: int = 1024
) -> jax.Array:
    """Flash-attention algorithm in pure JAX (online softmax over KV chunks).

    Memory is O(chunk_q * chunk_k) per (head, batch) instead of O(S^2); this
    is the jnp twin of the Pallas kernel and the path the 32k-prefill dry-run
    lowers.  Upper-triangle chunk pairs are skipped at runtime via lax.cond
    (the hillclimbed variant; see EXPERIMENTS.md §Perf).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    cq = min(chunk, s)
    ck = min(chunk, s)
    nq, nk = s // cq, s // ck
    qg = q.reshape(b, nq, cq, hkv, g, hd)

    k_chunks = k.reshape(b, nk, ck, hkv, hd)
    v_chunks = v.reshape(b, nk, ck, hkv, hd)

    def q_block(qi, q_blk):
        # q_blk: (b, cq, hkv, g, hd)
        def kv_step(carry, kj):
            acc, m, l = carry
            kc = k_chunks[:, kj]
            vc = v_chunks[:, kj]

            def compute(args):
                acc, m, l = args
                s_blk = jnp.einsum(
                    "bqkgd,btkd->bkgqt", q_blk, kc,
                    preferred_element_type=jnp.float32,
                ) * scale
                # Causal mask within the diagonal block.
                q_pos = qi * cq + jnp.arange(cq)
                k_pos = kj * ck + jnp.arange(ck)
                causal = q_pos[:, None] >= k_pos[None, :]
                s_blk = jnp.where(causal[None, None, None], s_blk, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
                p = jnp.exp(s_blk - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqt,btkd->bkgqd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32,
                )
                return acc_new, m_new, l_new

            acc, m, l = jax.lax.cond(
                kj * ck <= qi * cq + cq - 1,  # any overlap with causal region
                compute,
                lambda args: args,
                (acc, m, l),
            )
            return (acc, m, l), None

        acc0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b, hkv, g, cq, hd) -> (b, cq, hq, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, cq, hq, hd)

    outs = jax.lax.map(lambda qi: q_block(qi, qg[:, qi].transpose(0, 1, 2, 3, 4)), jnp.arange(nq))
    # outs: (nq, b, cq, hq, hd) -> (b, s, hq, hd)
    return jnp.transpose(outs, (1, 0, 2, 3, 4)).reshape(b, s, hq, hd).astype(q.dtype)


def _paged_decode_attend(q, k, v, cache, cache_len, block_tables, scale):
    """Paged decode / chunked-prefill attention: scatter the S new K/V
    positions into the shared block pool through the block table, then
    attend each query over its row's logical prefix.

    q/k/v: (B, S, H*, hd) fresh (rope'd) projections; cache leaves are block
    pools (N, bs, ...); block_tables (B, M) int32 with -1 marking
    unallocated (or force-masked) blocks — their writes DROP, which is how
    inactive rows and admission padding rows are silenced without branching.
    S == 1 is a decode step (Pallas kernel on TPU via ops dispatch); S > 1
    is one chunk of streaming prefill (jnp gather path; compute-bound).

    Quantized (int8) caches: decode attends the same dequantized view as
    the dense-slab path (bit-identical inputs).  Chunked prefill, however,
    attends the cache-consistent dequantized view of the prompt — the dense
    prefill branch attends raw fp K/V and only quantizes for storage — so
    prompt-end logits differ between layouts by the quantization error.
    """
    from repro.kernels.paged_attention.ops import gather_pages, paged_attention

    b, s = q.shape[:2]
    nb, bs = cache["k"].shape[:2]
    m = block_tables.shape[1]
    pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)  # (B, S) logical
    blk = pos // bs
    phys = jnp.take_along_axis(block_tables, jnp.minimum(blk, m - 1), axis=1)
    # Sentinel must be a POSITIVE out-of-bounds index: .at[...].set(mode=
    # "drop") normalizes negative indices NumPy-style BEFORE dropping, so -1
    # would silently write the last pool slot instead of dropping.
    flat = jnp.where((blk < m) & (phys >= 0), phys * bs + pos % bs, nb * bs)
    flat = flat.reshape(-1)

    def scat(pool, new):  # new: (B, S, ...) -> write at flat positions
        pf = pool.reshape(nb * bs, *pool.shape[2:])
        pf = pf.at[flat].set(
            new.reshape(b * s, *new.shape[2:]).astype(pool.dtype), mode="drop"
        )
        return pf.reshape(pool.shape)

    if "k_scale" in cache:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": scat(cache["k"], kq), "v": scat(cache["v"], vq),
            "k_scale": scat(cache["k_scale"], ks),
            "v_scale": scat(cache["v_scale"], vs),
        }
        k_sc, v_sc = new_cache["k_scale"], new_cache["v_scale"]
    else:
        new_cache = {"k": scat(cache["k"], k), "v": scat(cache["v"], v)}
        k_sc = v_sc = None

    if s == 1:
        out = paged_attention(
            q[:, 0], new_cache["k"], new_cache["v"], block_tables,
            cache_len + 1, k_scales=k_sc, v_scales=v_sc, scale=scale,
        )
        return out[:, None], new_cache
    # Chunked prefill: dense gathered view, causal vs each query's position.
    kg = gather_pages(new_cache["k"], block_tables)
    vg = gather_pages(new_cache["v"], block_tables)
    if k_sc is not None:
        kg = _dequantize_kv(kg, gather_pages(k_sc, block_tables), q.dtype)
        vg = _dequantize_kv(vg, gather_pages(v_sc, block_tables), q.dtype)
    t = kg.shape[1]
    valid = jnp.arange(t)[None, None, :] <= pos[:, :, None]  # (B, S, T)
    out = _naive_attention(q, kg, vg, valid[:, None, None], scale)
    return out, new_cache


def attention_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    mode: str = "causal",
    cache: Optional[Dict] = None,
    cache_len: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    chunked_threshold: int = 8192,
    attn_chunk: int = 1024,
    taps: Optional[Dict] = None,
    tap_prefix: str = "",
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output (B,S,D), updated cache or None)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    scale = 1.0 / math.sqrt(hd)
    use_rope = cfg.pos_emb == "rope" and mode != "cross"
    inv_freq = rope_frequencies(hd, cfg.rotary_pct, cfg.rope_theta) if use_rope else None

    if taps is not None:
        taps[f"{tap_prefix}.in"] = x

    q = _split_heads(linear(params["wq"], x), cfg.num_heads, hd)
    if mode == "cross":
        kv_src = memory
    else:
        kv_src = x
    if taps is not None and mode == "cross":
        taps[f"{tap_prefix}.kv_in"] = kv_src
    k = _split_heads(linear(params["wk"], kv_src), cfg.num_kv_heads, hd)
    v = _split_heads(linear(params["wv"], kv_src), cfg.num_kv_heads, hd)

    if use_rope:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)

    new_cache = None
    if mode == "decode" and block_tables is not None:
        out, new_cache = _paged_decode_attend(
            q, k, v, cache, cache_len, block_tables, scale
        )
    elif mode == "decode":
        assert cache is not None and cache_len is not None
        t_max = cache["k"].shape[1]
        # Write the S new K/V entries at each row's current length.  S == 1
        # is the classic decode step; S > 1 is a dense-slab chunk step
        # (speculative verification / catch-up decode): query i attends
        # positions <= cache_len + i, exactly mirroring the paged chunk
        # path.  Writes past max_len drop — they can only affect tokens the
        # engine truncates at its max_len/max_new budget anyway.
        idx = cache_len  # (B,)
        pos = idx[:, None] + jnp.arange(s, dtype=jnp.int32)  # (B, S)
        if "k_scale" in cache:  # int8-quantized cache
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            rows = jnp.arange(k.shape[0])[:, None]
            new_cache = {
                "k": _scatter_chunk(cache["k"], kq, pos),
                "v": _scatter_chunk(cache["v"], vq, pos),
                "k_scale": cache["k_scale"].at[rows, pos].set(ks, mode="drop"),
                "v_scale": cache["v_scale"].at[rows, pos].set(vs, mode="drop"),
            }
            k_cache = _dequantize_kv(new_cache["k"], new_cache["k_scale"], q.dtype)
            v_cache = _dequantize_kv(new_cache["v"], new_cache["v_scale"], q.dtype)
        else:
            k_cache = _scatter_chunk(cache["k"], k, pos)
            v_cache = _scatter_chunk(cache["v"], v, pos)
            new_cache = {"k": k_cache, "v": v_cache}
        valid = jnp.arange(t_max)[None, None, :] <= pos[:, :, None]  # (B,S,T)
        mask = valid[:, None, None]  # (B,1,1,S,T)
        out = _naive_attention(q, k_cache, v_cache, mask, scale)
    elif mode == "cross":
        t = k.shape[1]
        mask = jnp.ones((b, 1, 1, s, t), bool)
        out = _naive_attention(q, k, v, mask, scale)
    elif mode == "bidir":
        mask = jnp.ones((b, 1, 1, s, s), bool)
        out = _naive_attention(q, k, v, mask, scale)
    else:  # causal train/prefill
        if s >= chunked_threshold:
            out = chunked_causal_attention(q, k, v, scale, attn_chunk)
        else:
            causal = jnp.tril(jnp.ones((s, s), bool))
            mask = causal[None, None, None]
            out = _naive_attention(q, k, v, mask, scale)
        if cache is not None:
            t_max = cache["k"].shape[1]
            pad = [(0, 0), (0, t_max - s), (0, 0), (0, 0)]
            if "k_scale" in cache:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                pad3 = [(0, 0), (0, t_max - s), (0, 0)]
                new_cache = {
                    "k": jnp.pad(kq, pad),
                    "v": jnp.pad(vq, pad),
                    "k_scale": jnp.pad(ks, pad3),
                    "v_scale": jnp.pad(vs, pad3),
                }
            else:
                new_cache = {
                    "k": jnp.pad(k, pad).astype(cache["k"].dtype),
                    "v": jnp.pad(v, pad).astype(cache["v"].dtype),
                }

    merged = _merge_heads(out)
    if taps is not None:
        taps[f"{tap_prefix}.out_in"] = merged
    y = linear(params["wo"], merged)
    return y, new_cache


def _scatter_chunk(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """cache: (B, T, H, d), new: (B, S, H, d), pos: (B, S) -> write new[b, i]
    at cache[b, pos[b, i]].  Positions >= T drop (positive OOB only — the
    engine never produces negative write positions)."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b)[:, None], pos].set(
        new.astype(cache.dtype), mode="drop"
    )
