"""Decoder-only LM assembly (dense / MoE / hybrid / SSM / VLM)."""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NONE_PARALLEL, Parallelism

from .blocks import (
    group_apply,
    group_cache_init,
    group_init,
    group_layers,
    resolve_specs,
)
from .layers import (
    embed,
    embedding_init,
    learned_pos,
    learned_pos_init,
    linear,
    linear_init,
    norm_apply,
    norm_init,
    unembed,
)

VISION_FEATURE_DIM = 1024  # CLIP-L patch feature width (llava stub input)


class DecoderLM:
    """Functional decoder-only LM over plain dict pytrees.

    apply modes: "train" (causal, no cache), "prefill" (causal, fills cache),
    "decode" (single new token per row against the cache).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        par: Parallelism = NONE_PARALLEL,
        remat: bool = False,
        unroll: bool = False,
        seq_parallel: bool = False,
    ):
        self.cfg = cfg
        self.par = par
        self.remat = remat
        self.unroll = unroll
        # Sequence parallelism: residual stream sharded over the model axis
        # on the sequence dim between blocks; XLA turns the Megatron
        # all-reduce pairs into reduce-scatter + all-gather (half the wire
        # bytes, 1/TP the activation residency).  §Perf hillclimb lever.
        self.seq_parallel = seq_parallel
        self.specs = resolve_specs(cfg)
        self.groups = group_layers(self.specs)
        self.dtype = getattr(jnp, cfg.dtype)

    # ------------------------------------------------------------- params

    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, len(self.groups) + 4)
        params: Dict[str, Any] = {
            "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, self.dtype)
        }
        if cfg.pos_emb == "learned":
            params["pos"] = learned_pos_init(ks[1], cfg.max_seq, cfg.d_model, self.dtype)
        if cfg.frontend == "vision":
            pk = jax.random.split(ks[2], 2)
            params["projector"] = {
                "wi": linear_init(pk[0], VISION_FEATURE_DIM, cfg.d_model, self.dtype),
                "wo": linear_init(pk[1], cfg.d_model, cfg.d_model, self.dtype),
            }
        for i, g in enumerate(self.groups):
            params[f"g{i}"] = group_init(ks[3 + i], g, cfg, self.dtype, cross=False)
        params["final_norm"] = norm_init(cfg.norm, cfg.d_model, self.dtype)
        if not cfg.tie_embeddings:
            params["unembed"] = linear_init(
                ks[-1], cfg.d_model, cfg.vocab_size, self.dtype
            )
        return params

    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_quant: bool = False) -> Dict:
        dtype = dtype or self.dtype
        return {
            f"g{i}": group_cache_init(g, self.cfg, batch, max_len, dtype,
                                      cross=False, kv_quant=kv_quant)
            for i, g in enumerate(self.groups)
        }

    def init_paged_cache(self, num_blocks: int, block_size: int, dtype=None,
                         kv_quant: bool = False) -> Dict:
        """Block-pool KV cache shared by all rows (see attention.
        init_paged_kv_cache); only valid for pure-attention stacks —
        ``models.api.cache_layout`` reports which models qualify."""
        dtype = dtype or self.dtype
        from .blocks import group_paged_cache_init

        return {
            f"g{i}": group_paged_cache_init(g, self.cfg, num_blocks,
                                            block_size, dtype, kv_quant)
            for i, g in enumerate(self.groups)
        }

    # -------------------------------------------------------------- apply

    def apply(
        self,
        params: Mapping[str, Any],
        tokens: jax.Array,
        *,
        patches: Optional[jax.Array] = None,
        mode: str = "train",
        cache: Optional[Dict] = None,
        cache_len: Optional[jax.Array] = None,
        block_tables: Optional[jax.Array] = None,
        taps: Optional[Dict] = None,
        output: str = "logits",
    ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
        """Returns (logits-or-hidden, new_cache, aux_loss).  output="hidden"
        skips the unembed (chunked-loss path computes it in seq chunks)."""
        cfg = self.cfg
        par = self.par
        b, s_text = tokens.shape

        x = embed(params["embed"], tokens).astype(self.dtype)
        n_prefix = 0
        if patches is not None:
            if taps is not None:
                taps["projector.in"] = patches
            pv = jax.nn.gelu(linear(params["projector"]["wi"], patches.astype(self.dtype)))
            if taps is not None:
                taps["projector.mid"] = pv
            pv = linear(params["projector"]["wo"], pv)
            x = jnp.concatenate([pv, x], axis=1)
            n_prefix = patches.shape[1]
        s = x.shape[1]

        if mode == "decode":
            assert cache_len is not None
            # (B, S): one new token per row, or an S-token chunk streaming
            # into the (paged) cache at each row's current length.
            positions = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        if cfg.pos_emb == "learned":
            x = x + learned_pos(params["pos"], positions).astype(x.dtype)

        seq_axis = par.tp_axis if (self.seq_parallel and mode != "decode") else None
        x = par.constrain(x, par.dp, seq_axis, None)

        new_cache: Dict[str, Any] = {}
        aux_total = jnp.zeros((), jnp.float32)
        for i, g in enumerate(self.groups):
            x, nc, aux = group_apply(
                params[f"g{i}"], x, g, cfg,
                positions=positions, mode=mode,
                cache=None if cache is None else cache.get(f"g{i}"),
                cache_len=cache_len,
                par=par, taps=taps, tap_group=f"g{i}",
                remat=self.remat and mode == "train",
                unroll=self.unroll,
                block_tables=block_tables,
            )
            x = par.constrain(x, par.dp, seq_axis, None)
            if nc is not None:
                new_cache[f"g{i}"] = nc
            aux_total = aux_total + aux

        x = norm_apply(params["final_norm"], x)
        if taps is not None:
            taps["final.out_in"] = x
        if n_prefix:
            x = x[:, n_prefix:]
        if output == "hidden":
            return x, (new_cache or None), aux_total
        logits_params = params.get("unembed", params["embed"])
        logits = unembed(logits_params, x)
        logits = par.constrain(logits, par.dp, None, "model")
        return logits, (new_cache or None), aux_total

    # ---------------------------------------------------- compressible map

    def compressible_targets(self):
        """TargetSpecs for every factorizable matrix (DESIGN.md §7)."""
        from repro.core.plan import TargetSpec

        cfg = self.cfg
        targets = []
        d = cfg.d_model
        hq = cfg.num_heads * cfg.head_dim
        hkv = cfg.num_kv_heads * cfg.head_dim if cfg.num_kv_heads else 0

        def add(path, in_dim, out_dim, tap, stacked=()):
            targets.append(
                TargetSpec(
                    path=path, in_dim=in_dim, out_dim=out_dim,
                    gram_key=tap, stacked=stacked,
                )
            )

        for i, g in enumerate(self.groups):
            rep = (g.repeats,) if g.repeats > 1 else ()
            for j, (mixer, ffn) in enumerate(g.period):
                base = (f"g{i}", f"sub{j}")
                tap = f"g{i}/sub{j}"
                if mixer == "gqa":
                    add(base + ("attn", "wq"), d, hq, f"{tap}.attn.in", rep)
                    add(base + ("attn", "wk"), d, hkv, f"{tap}.attn.in", rep)
                    add(base + ("attn", "wv"), d, hkv, f"{tap}.attn.in", rep)
                    add(base + ("attn", "wo"), hq, d, f"{tap}.attn.out_in", rep)
                elif mixer == "mla":
                    m = cfg.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    add(base + ("attn", "wq_a"), d, m.q_lora_rank, f"{tap}.attn.in", rep)
                    add(base + ("attn", "wq_b"), m.q_lora_rank, cfg.num_heads * qk,
                        f"{tap}.attn.q_lora_in", rep)
                    add(base + ("attn", "wkv_a"), d, m.kv_lora_rank + m.qk_rope_head_dim,
                        f"{tap}.attn.in", rep)
                    add(base + ("attn", "wkv_b"), m.kv_lora_rank,
                        cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim),
                        f"{tap}.attn.kv_lora_in", rep)
                    add(base + ("attn", "wo"), cfg.num_heads * m.v_head_dim, d,
                        f"{tap}.attn.out_in", rep)
                elif mixer == "mamba":
                    mc = cfg.mamba
                    dt_rank = mc.dt_rank or -(-d // 16)
                    add(base + ("mamba", "in_proj"), d, 2 * mc.d_inner, f"{tap}.mamba.in", rep)
                    add(base + ("mamba", "x_proj"), mc.d_inner, dt_rank + 2 * mc.d_state,
                        f"{tap}.mamba.ssm_in", rep)
                    add(base + ("mamba", "dt_proj"), dt_rank, mc.d_inner,
                        f"{tap}.mamba.dt_in", rep)
                    add(base + ("mamba", "out_proj"), mc.d_inner, d, f"{tap}.mamba.out_in", rep)
                elif mixer == "rwkv":
                    for w, t in (("wr", "r"), ("wk", "k"), ("wv", "v"), ("wg", "g")):
                        add(base + ("rwkv_t", w), d, d, f"{tap}.rwkv_t.{t}_in", rep)
                    add(base + ("rwkv_t", "wo"), d, d, f"{tap}.rwkv_t.out_in", rep)

                if ffn == "mlp":
                    add(base + ("mlp", "wi"), d, cfg.d_ff, f"{tap}.mlp.in", rep)
                    if cfg.activation == "swiglu":
                        add(base + ("mlp", "wg"), d, cfg.d_ff, f"{tap}.mlp.in", rep)
                    add(base + ("mlp", "wo"), cfg.d_ff, d, f"{tap}.mlp.mid", rep)
                elif ffn == "moe":
                    m = cfg.moe
                    erep = rep + (m.num_experts,)
                    add(base + ("moe", "experts", "wi"), d, m.d_ff_expert,
                        f"{tap}.moe.expert_buf", erep)
                    add(base + ("moe", "experts", "wg"), d, m.d_ff_expert,
                        f"{tap}.moe.expert_buf", erep)
                    add(base + ("moe", "experts", "wo"), m.d_ff_expert, d,
                        f"{tap}.moe.expert_mid", erep)
                    if m.num_shared_experts:
                        fs = m.d_ff_expert * m.num_shared_experts
                        add(base + ("moe", "shared", "wi"), d, fs, f"{tap}.moe.shared_in", rep)
                        add(base + ("moe", "shared", "wg"), d, fs, f"{tap}.moe.shared_in", rep)
                        add(base + ("moe", "shared", "wo"), fs, d, f"{tap}.moe.shared_mid", rep)
                elif ffn == "cmix":
                    add(base + ("rwkv_c", "wk"), d, cfg.d_ff, f"{tap}.rwkv_c.k_in", rep)
                    add(base + ("rwkv_c", "wv"), cfg.d_ff, d, f"{tap}.rwkv_c.mid", rep)
                    add(base + ("rwkv_c", "wr"), d, d, f"{tap}.rwkv_c.r_in", rep)

        if cfg.frontend == "vision":
            targets.append(TargetSpec(("projector", "wi"), VISION_FEATURE_DIM, d,
                                      "projector.in"))
            targets.append(TargetSpec(("projector", "wo"), d, d, "projector.mid"))
        return targets
