"""Elastic scaling: remap a job onto a changed device set.

Checkpoints are topology-agnostic (logical arrays + spec rules), so elastic
rescale is: build the new mesh -> recompute shardings from the same rules ->
restore.  The policy layer here decides the new mesh shape when hosts are
lost (shrink the DP axes first — TP topology is fixed by the model), and
validates that the surviving device count supports it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def shrink_plan(
    current: MeshPlan, available_devices: int, tp_axis: str = "model"
) -> Optional[MeshPlan]:
    """Largest mesh fitting `available_devices` that keeps TP size fixed.

    DP-ish axes (everything but TP) absorb the loss, largest first; returns
    None when even TP alone no longer fits (job must abort).
    """
    tp_idx = current.axes.index(tp_axis)
    tp = current.shape[tp_idx]
    if available_devices < tp:
        return None
    budget = available_devices // tp
    dp_axes = [
        (i, s) for i, s in enumerate(current.shape) if i != tp_idx
    ]
    # Greedy: keep axis ratios, round down to powers of two of the original.
    new_shape = list(current.shape)
    total_dp = 1
    for i, s in dp_axes:
        total_dp *= s
    scale = budget / total_dp
    remaining = budget
    for i, s in sorted(dp_axes, key=lambda t: -t[1]):
        ns = max(1, min(s, int(s * scale)))
        # keep divisibility: largest power of two <= ns that divides budget
        while remaining % ns != 0 and ns > 1:
            ns -= 1
        new_shape[i] = ns
        remaining //= ns
    # Distribute any leftover onto the first DP axis.
    if remaining > 1:
        i0 = dp_axes[0][0]
        new_shape[i0] *= remaining
    plan = MeshPlan(tuple(new_shape), current.axes)
    if plan.size > available_devices:
        return None
    return plan


def validate_batch_divisibility(global_batch: int, plan: MeshPlan, dp_axes: Sequence[str]) -> bool:
    dp = 1
    for a, s in zip(plan.axes, plan.shape):
        if a in dp_axes:
            dp *= s
    return global_batch % dp == 0
