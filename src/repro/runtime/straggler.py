"""Straggler mitigation: step-time watchdog + backup-step dispatch.

On synchronous SPMD hardware a straggling host stalls every collective; the
mitigations that work at scale are (1) detecting the straggler fast, (2)
excluding it via elastic reshard, and (3) hiding transient stalls by
overlapping the data pipeline and checkpoint IO.  This module implements the
detection/decision layer; elastic.py performs the reshard.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    window: int = 32  # step-time history window
    slow_factor: float = 2.5  # step slower than median*factor => suspicious
    trip_count: int = 3  # consecutive suspicious steps => act


class StepTimeWatchdog:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(), clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.history: List[float] = []
        self._start: Optional[float] = None
        self._suspicious = 0
        self.trips = 0

    def step_start(self):
        self._start = self.clock()

    def step_end(self) -> str:
        """Returns 'ok' | 'slow' | 'trip'."""
        assert self._start is not None
        dur = self.clock() - self._start
        self._start = None
        return self.observe(dur)

    def observe(self, dur: float) -> str:
        """Classify an externally-timed step duration: 'ok'|'slow'|'trip'.

        The serving engine times steps itself (a pipelined ring keeps
        several in flight, so the single-slot step_start/step_end pair
        cannot bracket them) and feeds durations here.

        Only clean durations enter the median window: folding flagged
        steps in would let sustained degradation drag the median up
        until the watchdog stops tripping on it.
        """
        verdict = "ok"
        if len(self.history) >= 8:
            med = statistics.median(self.history[-self.cfg.window :])
            if dur > med * self.cfg.slow_factor:
                self._suspicious += 1
                verdict = "slow"
                if self._suspicious >= self.cfg.trip_count:
                    self._suspicious = 0
                    self.trips += 1
                    verdict = "trip"
            else:
                self._suspicious = 0
        if verdict == "ok":
            self.history.append(dur)
            if len(self.history) > 4 * self.cfg.window:
                del self.history[: -2 * self.cfg.window]
        return verdict

    @property
    def median_step(self) -> float:
        return statistics.median(self.history) if self.history else 0.0
