"""Fault tolerance: step guards, NaN/overflow policy, failure recovery.

At 1000+ nodes the failure model is: (a) hardware loss -> process dies ->
job restarts from the latest atomic checkpoint (manager.restore covers
this, including onto a *different* device count — elastic); (b) silent data
corruption / loss spikes -> detected by the step guard below, which skips
the update and optionally rolls back; (c) stragglers -> watchdog in
straggler.py.

The guard is jit-compatible: the skip decision is a lax.cond inside the
step, so no host round-trip on the hot path.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    max_loss: float = 1e4  # treat larger losses as divergence
    max_grad_norm: float = 1e4
    rollback_patience: int = 3  # consecutive bad steps before reload


def guarded_update(loss, grad_norm, new_tree, old_tree, cfg: GuardConfig):
    """jit-side: keep old (params, opt state) when the step looks corrupt.

    Returns (tree, bad_flag).  Both branches are pre-materialized trees, so
    this is a jnp.where select — cheap and overlap-friendly.
    """
    bad = (
        ~jnp.isfinite(loss)
        | (loss > cfg.max_loss)
        | ~jnp.isfinite(grad_norm)
        | (grad_norm > cfg.max_grad_norm)
    )
    keep = jax.tree.map(
        lambda n, o: jnp.where(bad, o, n), new_tree, old_tree
    )
    return keep, bad


class FaultHandler:
    """Host-side policy: counts consecutive bad steps, triggers reload."""

    def __init__(self, cfg: GuardConfig, manager=None):
        self.cfg = cfg
        self.manager = manager
        self.consecutive_bad = 0
        self.total_bad = 0
        self.reloads = 0

    def observe(self, bad: bool) -> str:
        """Returns action: 'ok' | 'skipped' | 'reload'."""
        if not bad:
            self.consecutive_bad = 0
            return "ok"
        self.consecutive_bad += 1
        self.total_bad += 1
        if (
            self.manager is not None
            and self.consecutive_bad >= self.cfg.rollback_patience
        ):
            self.consecutive_bad = 0
            self.reloads += 1
            logger.warning("fault handler: rollback to latest checkpoint")
            return "reload"
        logger.warning("fault handler: skipped corrupt step")
        return "skipped"


class HeartbeatMonitor:
    """Tracks per-host liveness (multi-host deployments feed this from the
    coordinator; here it is unit-tested with injected clocks)."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen = {h: clock() for h in range(n_hosts)}

    def beat(self, host: int):
        if host not in self.last_seen:
            raise KeyError(
                f"heartbeat from unknown host {host!r}; monitor tracks "
                f"hosts 0..{len(self.last_seen) - 1}")
        self.last_seen[host] = self.clock()

    def dead_hosts(self) -> list:
        now = self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_hosts()
