"""Sharding-drift audit: compiled in/out shardings vs ServingShardings pins.

The engine pins explicit NamedShardings on every root for two load-bearing
reasons: donated buffers only alias when the donated input's sharding
equals its output's, and an unpinned output lets GSPMD pick a layout the
NEXT step's input doesn't expect — a silent reshard (or recompile) per
step.  The pins are trusted at jit time; this audit closes the loop by
reading the COMPILED executable's in/out shardings back and comparing them
leaf-for-leaf (``Sharding.is_equivalent_to``, so NamedSharding vs
GSPMDSharding representations of the same placement agree).

Meshless roots have nothing to pin — reported as skipped, ok."""

from __future__ import annotations

import dataclasses
from typing import Any, List

import jax


@dataclasses.dataclass
class ShardingAudit:
    root: str
    checked_leaves: int
    mismatches: List[str]
    skipped: bool
    ok: bool


def _expected_leaves(entry: Any, n_actual: int, where: str):
    """An expected-sharding entry is either one Sharding broadcast over the
    arg's leaves or a tree matching it leaf-for-leaf."""
    leaves = jax.tree.leaves(
        entry, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    if len(leaves) == 1 and n_actual > 1:
        return leaves * n_actual
    if len(leaves) != n_actual:
        raise ValueError(
            f"{where}: expected-sharding tree has {len(leaves)} leaves "
            f"for {n_actual} actual leaves"
        )
    return leaves


def _compare(exp_entry, act_entry, aval_entry, where: str,
             mismatches: List[str]) -> int:
    avals = jax.tree.leaves(aval_entry)
    if not avals:
        return 0  # e.g. a None block_tables arg on the dense layout
    # Leaves the executable pruned (donated-but-unused, or params a root
    # never reads — a draft prefill skips the unembed) appear as None in
    # the compiled sharding tree; keep them as placeholders so positions
    # still line up with the aval leaves, then skip them.
    act = jax.tree.flatten(act_entry, is_leaf=lambda x: x is None)[0]
    if len(act) != len(avals):
        mismatches.append(
            f"{where}: compiled sharding tree has {len(act)} leaves for "
            f"{len(avals)} input leaves")
        return len(avals)
    exp = _expected_leaves(exp_entry, len(avals), where)
    n = 0
    for i, (e, a, av) in enumerate(zip(exp, act, avals)):
        if a is None:
            continue  # pruned from the executable: nothing to drift
        n += 1
        ndim = len(av.shape)
        if not e.is_equivalent_to(a, ndim):
            mismatches.append(
                f"{where}[leaf {i}]: pinned {e!r} but compiled to {a!r}"
            )
    return n


def audit_sharding(art) -> ShardingAudit:
    if art.expected_shardings is None or art.compiled is None:
        return ShardingAudit(root=art.name, checked_leaves=0,
                             mismatches=[], skipped=True, ok=True)
    in_exp, out_exp = art.expected_shardings
    act_in, act_kw = art.compiled.input_shardings
    mismatches: List[str] = []
    checked = 0
    for i, (e, a, av) in enumerate(zip(in_exp, act_in, art.args)):
        checked += _compare(e, a, av, f"{art.name}:in arg{i}", mismatches)
    act_out = art.compiled.output_shardings
    outs = list(art.out_avals)
    for i, (e, a, av) in enumerate(zip(out_exp, act_out, outs)):
        checked += _compare(e, a, av, f"{art.name}:out {i}", mismatches)
    return ShardingAudit(root=art.name, checked_leaves=checked,
                         mismatches=mismatches, skipped=False,
                         ok=not mismatches)
