"""Pallas VMEM/tiling lint: per-grid-step on-chip bytes, statically.

Every serving kernel package exports ``vmem_tiles(...)`` — a plain-data
inventory of the buffers resident in VMEM during one grid step, mirroring
its BlockSpecs and scratch_shapes (streamed BlockSpec operands count twice
for Pallas's automatic double-buffering; explicit DMA rings carry their 2
slots in their own leading dim).  This module does the arithmetic the
hardware will do:

  * pads each tile to the TPU register tiling for its dtype — (8, 128)
    f32, (16, 128) bf16, (32, 128) int8/fp8 on the two minor dims — and
    flags tiles whose minor dims are NOT already multiples (padding waste
    and, for the lane dim, strided DMAs);
  * sums padded bytes x buffers against the per-core VMEM budget
    (~16 MiB; the lint uses a conservative 90% of it because the compiler
    keeps a slice for itself).

Also home to the packed paged-attention decode cost model (FLOPs/HBM
bytes) that benchmarks/roofline.py stamps — the kernel's arithmetic
intensity is a static function of its geometry."""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

LANE = 128
_SUBLANE_BY_ITEMSIZE = {8: 8, 4: 8, 2: 16, 1: 32}

VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET = int(VMEM_BYTES * 0.9)  # compiler keeps a slice


def _dtype_itemsize(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        # jnp dtypes like bfloat16 objects
        return np.dtype(str(dtype)).itemsize


def sublane(dtype) -> int:
    return _SUBLANE_BY_ITEMSIZE[_dtype_itemsize(dtype)]


def padded_shape(shape, dtype) -> tuple:
    """Shape after padding the two minor dims to the dtype's register tile
    ((sublane, 128)); scalars/vectors pad as a 1-row tile."""
    shape = tuple(int(s) for s in shape)
    if not shape:
        shape = (1,)
    if len(shape) == 1:
        shape = (1,) + shape
    s = sublane(dtype)
    lead, m2, m1 = shape[:-2], shape[-2], shape[-1]
    return lead + (-(-m2 // s) * s, -(-m1 // LANE) * LANE)


@dataclasses.dataclass
class TileReport:
    name: str
    shape: tuple
    dtype: str
    buffers: int
    raw_bytes: int
    padded_bytes: int
    aligned: bool


@dataclasses.dataclass
class KernelLint:
    kernel: str
    tiles: List[TileReport]
    vmem_bytes: int        # sum of padded bytes x buffers
    vmem_limit: int
    fits: bool
    misaligned: List[str]

    @property
    def ok(self) -> bool:
        return self.fits


def kernel_lint(kernel: str, tiles: List[Dict],
                vmem_limit: int = VMEM_BUDGET) -> KernelLint:
    """Lint one kernel's ``vmem_tiles()`` inventory."""
    reports: List[TileReport] = []
    total = 0
    misaligned: List[str] = []
    for t in tiles:
        shape, dtype = tuple(t["shape"]), t["dtype"]
        buffers = int(t.get("buffers", 1))
        item = _dtype_itemsize(dtype)
        raw = int(np.prod(shape, dtype=np.int64)) * item
        pshape = padded_shape(shape, dtype)
        padded = int(np.prod(pshape, dtype=np.int64)) * item
        aligned = pshape == (shape if len(shape) > 1 else (1,) + shape)
        if not aligned:
            misaligned.append(
                f"{t['name']}: {shape} {dtype} pads to {pshape} "
                f"(sublane {sublane(dtype)} x lane {LANE})"
            )
        reports.append(TileReport(
            name=t["name"], shape=shape, dtype=str(dtype), buffers=buffers,
            raw_bytes=raw, padded_bytes=padded, aligned=aligned,
        ))
        total += padded * buffers
    return KernelLint(kernel=kernel, tiles=reports, vmem_bytes=total,
                      vmem_limit=vmem_limit, fits=total <= vmem_limit,
                      misaligned=misaligned)


def serving_kernel_lints(cfg, *, max_batch: int = 8, max_len: int = 256,
                         block_size: int = 16, kv_quant: bool = False,
                         gram_rows: int = 2048,
                         vmem_limit: int = VMEM_BUDGET) -> List[KernelLint]:
    """Lint every Pallas kernel this model config's serving path can reach,
    with tile geometry derived from the config (not hand-entered)."""
    from repro.kernels.flash_attention import flash_attention as fa
    from repro.kernels.gram import gram as gram_k
    from repro.kernels.nested_lowrank import nested_lowrank as nlr
    from repro.kernels.paged_attention import paged_attention as pa
    from repro.kernels.rwkv6 import rwkv6 as rk

    dtype = cfg.dtype
    out: List[KernelLint] = []
    has_attn = cfg.attention != "none" and any(
        m == "attn" for m in cfg.mixer_pattern)
    if has_attn:
        out.append(kernel_lint(
            "paged_attention",
            pa.vmem_tiles(max_batch, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim, block_size, dtype=dtype,
                          quant=kv_quant),
            vmem_limit,
        ))
        out.append(kernel_lint(
            "flash_attention",
            fa.vmem_tiles(max_len, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim, dtype=dtype),
            vmem_limit,
        ))
    # Decode-shaped nested-lowrank matmul of the largest compressed layer
    # (d_model -> d_ff up-projection) at the mildest compression the
    # planner emits (ratio 0.2) — the largest rank serving could ever see.
    # The dispatcher (ops.py) falls back to the XLA matmul when the
    # resident factors overflow its VMEM gate, so the lint walks the rank
    # down to the largest geometry the gate actually admits to Pallas.
    from repro.core.ratio import rank_for_ratio

    k1 = max(8, rank_for_ratio(cfg.d_model, cfg.d_ff, 0.2, multiple_of=8))
    while k1 > 8 and nlr.kernel_vmem_bytes(
            max_batch, cfg.d_model, cfg.d_ff, k1, max(8, k1 // 2),
            dtype=dtype) > min(vmem_limit, nlr.VMEM_LIMIT_BYTES):
        k1 -= 8
    out.append(kernel_lint(
        "nested_lowrank",
        nlr.vmem_tiles(max_batch, cfg.d_model, cfg.d_ff, k1,
                       max(8, k1 // 2), dtype=dtype),
        vmem_limit,
    ))
    out.append(kernel_lint(
        "gram",
        gram_k.vmem_tiles(cfg.d_model, gram_rows, dtype=dtype),
        vmem_limit,
    ))
    if cfg.rwkv is not None or "rwkv" in cfg.mixer_pattern:
        out.append(kernel_lint(
            "rwkv6",
            rk.vmem_tiles(max_len, cfg.d_model, dtype=dtype),
            vmem_limit,
        ))
    return out


# ------------------------------------------------ paged-attention roofline

def paged_attention_cost(batch: int, num_q_heads: int, num_kv_heads: int,
                         head_dim: int, block_size: int, mean_len: int,
                         *, dtype_bytes: int = 2, kv_bytes: int = None,
                         quant: bool = False,
                         rows_per_pack: Optional[int] = None) -> Dict:
    """Static FLOP/HBM-byte model of one packed paged-attention decode call.

    ``flops_useful`` counts the attention math the model needs (QK^T + PV:
    4 * B * Hq * hd per cached token); ``flops_mxu`` what the packed kernel
    actually issues — each R-row pack shares its page loop, so the MXU
    computes an (R*G, R*bs) score tile whose off-diagonal quadrants are
    masked junk (factor ~R).  Bytes stream every live page's K and V (plus
    scales when int8-quantized) once, q/out once."""
    from repro.kernels.paged_attention.paged_attention import (
        default_rows_per_pack,
    )

    g = max(1, num_q_heads // max(1, num_kv_heads))
    hkv = max(1, num_kv_heads)
    if kv_bytes is None:
        kv_bytes = 1 if quant else dtype_bytes
    r = (default_rows_per_pack(batch, g) if rows_per_pack is None
         else max(1, rows_per_pack))
    pages = math.ceil(max(1, mean_len) / block_size)
    flops_useful = 4 * batch * num_q_heads * head_dim * mean_len
    # Per pack, per page, per kv head: 2*(R*G)*hd*(R*bs) + 2*(R*G)*(R*bs)*hd
    packs = math.ceil(batch / r)
    flops_mxu = packs * pages * hkv * 4 * (r * g) * (r * block_size) * head_dim
    page_bytes = pages * block_size * hkv * head_dim * kv_bytes * 2
    scale_bytes = (pages * block_size * hkv * 4 * 2) if quant else 0
    q_bytes = batch * num_q_heads * head_dim * dtype_bytes * 2  # q + out
    hbm = batch * (page_bytes + scale_bytes) + q_bytes
    return {
        "rows_per_pack": r,
        "pages_per_row": pages,
        "flops_useful": flops_useful,
        "flops_mxu": flops_mxu,
        "hbm_bytes": hbm,
        "intensity": flops_useful / max(1, hbm),
    }
