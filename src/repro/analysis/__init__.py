"""Static contract auditor for the serving jit roots.

Traces every root in launch/steps.serving_root_registry to its jaxpr /
lowered stablehlo / compiled executable from ABSTRACT inputs (no decode step
runs, no cache is allocated) and checks the contracts the engine's
performance rests on:

  * transfers  — no host communication inside a root; steady-state roots
                 emit exactly ONE D2H output (the sampled-token vector).
  * donation   — every donated argnum's leaves actually alias an output in
                 the lowered computation (a dropped alias is a silent
                 per-step cache copy).
  * sharding   — compiled in/out shardings match the ServingShardings pins
                 leaf-for-leaf (drift means implicit resharding per step).
  * dtypes     — no f64 anywhere; no accidental fp32 upcast of large
                 bf16/f16 operands (params/cache scale); jaxpr-level walk.
  * pallas     — per-grid-step VMEM bytes of the serving kernels (from
                 their BlockSpecs + DMA rings) fit the per-core budget,
                 tiles land on sublane/lane boundaries for their dtype.
  * interleave — exhaustive enumeration of short BlockAllocator x pipeline
                 -ring schedules: no double-free, FIFO host-live <=>
                 device-active.

CLI: ``python -m repro.analysis.run --config llama-7b --layout both``.
"""

from .donation import audit_donation
from .dtypes import audit_dtypes
from .interleave import check_interleavings
from .pallas_lint import kernel_lint, serving_kernel_lints
from .roots import RootArtifact, audit_roots, make_root_context, trace_root
from .sharding_drift import audit_sharding
from .transfers import audit_transfers

__all__ = [
    "RootArtifact",
    "audit_donation",
    "audit_dtypes",
    "audit_roots",
    "audit_sharding",
    "audit_transfers",
    "check_interleavings",
    "kernel_lint",
    "make_root_context",
    "serving_kernel_lints",
    "trace_root",
]
