"""Donation audit: every donated argnum must alias an output buffer.

``jax.jit(donate_argnums=...)`` is a REQUEST: jax matches each donated
input leaf to a compatible output (shape/dtype/sharding) and records the
pair as a ``tf.aliasing_output`` attribute on the lowered main function's
parameter.  When no compatible output exists — someone reshaped a state
leaf, changed a dtype, dropped an output — the donation silently degrades
to a per-step COPY of that buffer (jax warns once at compile; nobody reads
warnings in a serving binary).  For a multi-MB KV cache that is the exact
copy the donation contract exists to prevent, so the auditor pins it
statically: the number of aliased parameters in the lowered computation
must equal the donated leaf count.

Per-arg attribution: lowered parameters appear in flattened-arg order, so
when none were pruned (every donated arg is used by construction — it
feeds an output) each missing alias names its offending argnum."""

from __future__ import annotations

import dataclasses
import re
from typing import List, Tuple

import jax

_ARG_RE = re.compile(r"%arg(\d+):")
_MAIN_RE = re.compile(r"func\.func\s+(?:public\s+)?@main\(")


@dataclasses.dataclass
class DonationAudit:
    root: str
    donated_args: Tuple[int, ...]
    expected_aliases: int   # donated leaves
    actual_aliases: int     # tf.aliasing_output params in the lowering
    missing: List[str]      # per-arg attribution when derivable
    ok: bool
    notes: List[str]


def _main_signature(text: str) -> str:
    """The argument list of the lowered module's @main (paren-balanced)."""
    m = _MAIN_RE.search(text)
    if m is None:
        return ""
    i = m.end() - 1  # at the opening paren
    depth = 0
    for j in range(i, len(text)):
        c = text[j]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return text[i:j]
    return text[i:]


def audit_donation(art) -> DonationAudit:
    donate = tuple(art.spec.donate)
    leaf_counts = [len(jax.tree.leaves(a)) for a in art.args]
    expected = sum(leaf_counts[d] for d in donate)

    sig = _main_signature(art.lowered.as_text())
    # Split the signature into per-parameter chunks: each starts at %argN.
    chunks = re.split(r"(?=%arg\d+:)", sig)
    chunks = [c for c in chunks if _ARG_RE.match(c)]
    aliased_params = [int(_ARG_RE.match(c).group(1)) for c in chunks
                      if "tf.aliasing_output" in c]
    actual = len(aliased_params)

    notes: List[str] = []
    missing: List[str] = []
    total_leaves = sum(leaf_counts)
    if len(chunks) == total_leaves:
        # No pruning: flat param index -> argnum is the cumulative-count map,
        # so missing aliases can be attributed to their donated arg.
        starts = []
        acc = 0
        for n in leaf_counts:
            starts.append(acc)
            acc += n
        aliased = set(aliased_params)
        for d in donate:
            span = range(starts[d], starts[d] + leaf_counts[d])
            lost = [p for p in span if p not in aliased]
            if lost:
                missing.append(
                    f"arg {d}: {len(lost)}/{leaf_counts[d]} donated "
                    f"leaves unaliased (params {lost[:4]}"
                    f"{'...' if len(lost) > 4 else ''})"
                )
    elif actual < expected:
        notes.append(
            f"lowered signature has {len(chunks)} params for "
            f"{total_leaves} arg leaves (args pruned); alias count "
            "compared without per-arg attribution"
        )

    ok = actual >= expected
    if not ok:
        notes.append(
            f"{expected - actual} donated leaves do not alias any output — "
            "each one is a silent per-step buffer copy"
        )
    return DonationAudit(root=art.name, donated_args=donate,
                         expected_aliases=expected, actual_aliases=actual,
                         missing=missing, ok=ok, notes=notes)
