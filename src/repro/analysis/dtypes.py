"""Dtype/promotion lint: walk a root's jaxpr for silent precision drift.

Three classes of violation:

  * any f64 value ANYWHERE (a stray python float in a jnp op with x64
    enabled, or an un-annotated numpy input) — serving never wants f64;
  * large bf16/f16 -> f32 convert_element_type ops: upcasting a logits row
    for a softmax is intended, upcasting the PARAMS or the KV CACHE (the
    compression's whole payoff) is a 2x HBM/bandwidth regression.  "Large"
    defaults to half the biggest param leaf, so the threshold scales with
    the model instead of hard-coding an element count;
  * weak-type widening: a weakly-typed f32 scalar meeting a bf16 tensor
    promotes the TENSOR in jax's promotion lattice — flagged via the same
    convert walk (the widening materializes as a convert of the tensor).

The walk descends into scan/while/cond/pjit sub-jaxprs but NOT into
pallas_call bodies: in-kernel fp32 accumulation (flash softmax, gram,
nested-lowrank scratch) is deliberate and stays in VMEM."""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import numpy as np

_SMALL = ("bfloat16", "float16")


@dataclasses.dataclass
class DtypeAudit:
    root: str
    upcast_threshold_elems: int
    f64_ops: List[str]
    large_upcasts: List[str]
    ok: bool


def _sub_jaxprs(v: Any) -> List[Any]:
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(_sub_jaxprs(x))
        return out
    if hasattr(v, "eqns"):          # Jaxpr
        return [v]
    if hasattr(v, "jaxpr"):         # ClosedJaxpr
        return [v.jaxpr]
    return []


def _walk(jaxpr, visit) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue  # in-tile fp32 accumulation is intended
        visit(eqn)
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                _walk(sub, visit)


def default_upcast_threshold(params_avals) -> int:
    """Half the largest param leaf (floor 2**16 elements): big enough to
    pass per-row logits softmaxes, small enough to catch a whole-cache or
    whole-matrix upcast."""
    biggest = max(
        (int(np.prod(leaf.shape, dtype=np.int64))
         for leaf in jax.tree.leaves(params_avals)),
        default=0,
    )
    return max(1 << 16, biggest // 2)


def audit_dtypes(art, upcast_threshold: Optional[int] = None) -> DtypeAudit:
    if upcast_threshold is None:
        upcast_threshold = default_upcast_threshold(art.args[0])
    f64: List[str] = []
    upcasts: List[str] = []

    def visit(eqn) -> None:
        for var in eqn.outvars:
            aval = var.aval
            dt = getattr(aval, "dtype", None)
            try:
                is_f64 = dt is not None and np.dtype(dt) == np.float64
            except TypeError:
                continue  # extended dtypes (PRNG keys) have no numpy dtype
            if is_f64:
                f64.append(f"{eqn.primitive.name} -> f64 {aval.shape}")
        if eqn.primitive.name != "convert_element_type":
            return
        (inv,) = eqn.invars
        in_aval = getattr(inv, "aval", None)
        in_dt = getattr(in_aval, "dtype", None)
        if in_dt is None:
            return
        new_dt = np.dtype(eqn.params.get("new_dtype"))
        elems = int(np.prod(in_aval.shape, dtype=np.int64))
        if (str(in_dt) in _SMALL and new_dt == np.float32
                and elems >= upcast_threshold):
            upcasts.append(
                f"{in_dt} -> f32 on {in_aval.shape} ({elems} elems)"
            )

    _walk(art.jaxpr.jaxpr, visit)
    return DtypeAudit(root=art.name,
                      upcast_threshold_elems=upcast_threshold,
                      f64_ops=f64, large_upcasts=upcasts,
                      ok=not f64 and not upcasts)
