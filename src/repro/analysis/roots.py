"""Trace serving jit roots to lowered/compiled artifacts from abstract
inputs.

The registry (launch/steps.serving_root_registry) supplies the builder,
donate_argnums, abstract input avals and sharding hook for every root; this
module jits each root exactly the way the engine does (same donation, same
pinned shardings) and lowers it with ShapeDtypeStructs — so the audited
computation is byte-for-byte the one a running engine would execute, but
nothing is allocated and no step runs.

Spec roots take DRAFT params as arg 0; the auditor traces them with the
TARGET's param avals (identical architecture — any well-formed params
pytree for the model lowers the same ops), which keeps the audit free of a
compression pass."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.launch.steps import (
    RootContext,
    RootSpec,
    ServingShardings,
    named,
    serving_root_registry,
)
from repro.models.api import (
    cache_layout,
    paged_cache_block_axes,
    prefill_pad_safe,
    serving_cache_pspecs,
)


@dataclasses.dataclass
class RootArtifact:
    """One traced serving root: everything the audits consume."""

    spec: RootSpec
    ctx: RootContext
    args: Tuple[Any, ...]            # positional aval pytrees
    out_avals: Any                   # output aval pytree (tuple of trees)
    jaxpr: Any                       # ClosedJaxpr of the unjitted fn
    lowered: Any                     # jax.stages.Lowered
    compiled: Any                    # jax.stages.Compiled (None if skipped)
    expected_shardings: Optional[Tuple[Any, Any]]  # (in, out) pins or None

    @property
    def name(self) -> str:
        return self.spec.name


def make_root_context(model, *, par=None, max_batch: int = 8,
                      max_len: int = 256, kv_quant: bool = False,
                      prefill_chunk: int = 64, block_size: int = 16,
                      num_blocks: Optional[int] = None,
                      spec_k: int = 4, bucket: int = 16) -> RootContext:
    """A RootContext mirroring ServingEngine's geometry resolution: DP
    shard count falls back to 1 when max_batch doesn't divide DP (the
    engine then keeps slots/pools replicated), and bucketed admission
    follows prefill_pad_safe."""
    dp_shards = 1
    if par is not None and getattr(par, "active", False):
        dp_size = int(np.prod([par.mesh.shape[a] for a in par.dp_axes]))
        dp_shards = dp_size if max_batch % dp_size == 0 else 1
    return RootContext(
        model=model, max_batch=max_batch, max_len=max_len,
        kv_quant=kv_quant, prefill_chunk=prefill_chunk,
        block_size=block_size, num_blocks=num_blocks, spec_k=spec_k,
        bucket=bucket, bucketed=prefill_pad_safe(model),
        dp_shards=dp_shards,
    )


def make_shardings(ctx: RootContext, layout: str, params_avals,
                   par) -> ServingShardings:
    """The ServingShardings bundle the engine would pin for this geometry
    (paged pools over their block dim when slots divide DP, else
    replicated; dense slab over its batch dim)."""
    from jax.sharding import PartitionSpec as P

    model = ctx.model
    if layout == "paged":
        pools = ctx.pool_avals()
        if ctx.dp_shards > 1:
            axes = paged_cache_block_axes(model, ctx.resolved_num_blocks,
                                          ctx.block_size,
                                          kv_quant=ctx.kv_quant)
            pspecs = serving_cache_pspecs(
                model, par, num_blocks=ctx.resolved_num_blocks,
                block_size=ctx.block_size, kv_quant=ctx.kv_quant,
                axes=axes, shapes=pools,
            )
        else:
            pspecs = jax.tree.map(lambda leaf: P(), pools)
        cache_sh = named(pspecs, par.mesh)
    else:
        cache = ctx.cache_avals()
        cache_sh = named(
            serving_cache_pspecs(model, par, max_batch=ctx.max_batch,
                                 max_len=ctx.max_len,
                                 kv_quant=ctx.kv_quant, shapes=cache),
            par.mesh,
        )
    return ServingShardings(par, params_avals, cache_sh, ctx.max_batch)


def trace_root(spec: RootSpec, ctx: RootContext, params_avals,
               sh: Optional[ServingShardings] = None,
               compile: bool = True) -> RootArtifact:
    """Lower (and compile) one root exactly as the engine jits it."""
    args = spec.abstract_inputs(ctx, params_avals)
    fn = spec.build(ctx)
    sh_pair = None
    kw: Dict[str, Any] = {}
    if sh is not None:
        draft_sh = sh.params if spec.needs_draft else None
        sh_pair = spec.shardings(sh, ctx, draft_sh)
        kw = {"in_shardings": sh_pair[0], "out_shardings": sh_pair[1]}
    lowered = jax.jit(fn, donate_argnums=spec.donate, **kw).lower(*args)
    compiled = lowered.compile() if compile else None
    jaxpr = jax.make_jaxpr(fn)(*args)
    out_avals = jax.eval_shape(fn, *args)
    return RootArtifact(spec=spec, ctx=ctx, args=args, out_avals=out_avals,
                        jaxpr=jaxpr, lowered=lowered, compiled=compiled,
                        expected_shardings=sh_pair)


def audit_roots(model, params_avals, *, par=None, layout: Optional[str] = None,
                spec: bool = True, compile: bool = True,
                **ctx_kw) -> List[RootArtifact]:
    """Trace every registry root for one cache layout.  ``layout=None``
    resolves the model's native layout; ``spec`` adds the speculative roots
    when the model supports them (paged-capable caches only, matching the
    engine's constructor check)."""
    native = cache_layout(model)
    layout = layout or native
    if layout == "paged" and native != "paged":
        raise ValueError(
            f"model {model.cfg.name!r} has cache layout {native!r}; "
            "cannot audit paged roots"
        )
    spec = spec and native == "paged"  # spec roots need paged-capable caches
    ctx = make_root_context(model, par=par, **ctx_kw)
    sh = None
    if par is not None and getattr(par, "active", False):
        sh = make_shardings(ctx, layout, params_avals, par)
    return [trace_root(r, ctx, params_avals, sh, compile=compile)
            for r in serving_root_registry(layout, spec=spec)]
