"""Exhaustive-interleaving checker: BlockAllocator x pipeline ring.

The serving engine overlaps device steps with host bookkeeping: dispatch
pushes an in-flight entry (the step's active-row mask) onto a ring of
depth D, and the host consumes the OLDEST entry's tokens later — possibly
after the slot set has changed.  The allocator invariants that make this
safe (a freed block is never still referenced by an in-flight step; a
retire inside an older entry doesn't corrupt a younger one) are enforced
by conventions scattered across ``engine.step`` / ``_commit_decode`` /
``admit``: admission and defrag only run on a DRAINED ring, retirement
frees exactly once, and consumption is FIFO.

This module model-checks those conventions by driving the REAL
:class:`repro.serving.kvcache.allocator.BlockAllocator` (not a toy copy)
through every interleaving of abstract engine operations up to a bounded
schedule length, with the device's finish choice made adversarially
(every subset of live masked rows can finish at every consume).  After
every operation it asserts:

  * block conservation — free + owned partition [0, num_blocks), no
    duplicates, no losses;
  * ownership exactness — allocator owners == live slots, every live slot
    holds >= 1 block (a live slot with no blocks means its cache space
    was freed while the device can still write it);
  * retire-frees-once — freeing a finishing slot returns a non-empty
    block list (empty == double free / ghost retire);
  * ring FIFO monotonicity — within one in-flight burst (no admission can
    interleave: it requires a drained ring) masks only shrink, so a row
    live in a younger entry was live in every older one — the assumption
    ``_commit_decode``'s ``mask & live`` skip relies on;
  * defrag soundness — the move map returned by ``defrag()`` preserves
    per-owner block counts and conservation.

The scheduler PR widened the operation alphabet: ``grow`` (on-demand
block growth — legal mid-flight, appending never invalidates an older
entry), ``preempt`` (victim eviction, reprefill or swap flavour —
requires a drained ring exactly like admission/defrag), and ``resume``
(re-admission of a preempted slot; a swap resume must get back exactly
the block count it saved).  A per-slot reservation ledger is checked
against the real allocator after every move, so an engine that grows a
row twice while recording the growth once (``double_grow``) is caught
as ledger drift even though block conservation still holds.

The fault-tolerance PR widened it again: ``cancel`` (user abort of a
live row — the engine drains the ring before touching device state, so
the move is gated on a drained ring and must free the row's blocks
exactly once), ``expire`` (deadline shed of a preempted/parked request
— host-only bookkeeping, its blocks were already released at eviction),
and ``fault_retire`` (quarantine: the oldest in-flight entry is consumed
and one of its masked rows retires to the preempted-reprefill state for
a backoff retry instead of finishing).  ``cancel_double_free`` seeds the
classic cancel/retire race: cancel frees a row that a concurrent
retirement already freed, which the retire-frees-once invariant reports
as an empty second free.

``bug=`` injects a deliberate violation of one convention so tests can
prove the checker actually catches each class (see ``BUGS``)."""

from __future__ import annotations

import dataclasses
from itertools import chain, combinations
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.serving.kvcache.allocator import BlockAllocator

#: Injectable convention violations (for seeded self-tests).
BUGS = (
    "double_free",       # retire frees the slot twice
    "free_on_dispatch",  # blocks freed at dispatch while step is in flight
    "leak_on_retire",    # retire drops the slot without freeing its blocks
    "admit_unsynced",    # admission without draining the ring first
    "double_grow",       # grow allocates twice but records one block
    "preempt_in_flight", # preemption without draining the ring first
    "cancel_double_free",  # cancel frees a row its retirement already freed
)

_Entry = FrozenSet[int]          # active-row mask at dispatch
_Snap = Tuple                    # hashable allocator snapshot


def _snapshot(alloc: BlockAllocator) -> _Snap:
    return (
        tuple(tuple(f) for f in alloc._free),
        tuple(sorted((k, tuple(v)) for k, v in alloc._owned.items())),
    )


def _restore(alloc: BlockAllocator, snap: _Snap) -> None:
    free, owned = snap
    alloc._free = [list(f) for f in free]
    alloc._owned = {k: list(v) for k, v in owned}


def _subsets(s: FrozenSet[int]):
    items = sorted(s)
    return chain.from_iterable(
        combinations(items, r) for r in range(len(items) + 1))


@dataclasses.dataclass
class InterleaveReport:
    num_slots: int
    num_blocks: int
    depth: int
    max_ops: int
    states_explored: int
    schedules_explored: int
    violations: List[str]
    bug: Optional[str] = None

    @property
    def ok(self) -> bool:
        return not self.violations


class _Model:
    """One engine-state: real allocator + host_live set + in-flight ring."""

    def __init__(self, num_slots: int, num_blocks: int, depth: int,
                 bug: Optional[str]):
        self.alloc = BlockAllocator(num_blocks)
        self.num_slots = num_slots
        self.num_blocks = num_blocks
        self.depth = depth
        self.bug = bug
        self.host_live: FrozenSet[int] = frozenset()
        self.ring: Tuple[_Entry, ...] = ()
        # scheduler's ledger: expected block count per live slot
        self.lengths: Dict[int, int] = {}
        # preempted slots awaiting resume: slot -> (mode, saved_blocks)
        self.preempted: Dict[int, Tuple[str, int]] = {}

    # ------------------------------------------------------------- state io

    def key(self):
        return (_snapshot(self.alloc), self.host_live, self.ring,
                tuple(sorted(self.lengths.items())),
                tuple(sorted(self.preempted.items())))

    def set_key(self, key) -> None:
        snap, self.host_live, self.ring, lengths, preempted = key
        _restore(self.alloc, snap)
        self.lengths = dict(lengths)
        self.preempted = dict(preempted)

    # ----------------------------------------------------------- invariants

    def check(self, op: str, violations: List[str]) -> None:
        a = self.alloc
        free = [b for f in a._free for b in f]
        owned = [b for ids in a._owned.values() for b in ids]
        both = free + owned
        if len(both) != len(set(both)):
            violations.append(
                f"{op}: duplicate block id (free={free}, owned={owned})")
        if set(both) != set(range(self.num_blocks)):
            violations.append(
                f"{op}: conservation broken — free+owned covers "
                f"{sorted(set(both))}, want 0..{self.num_blocks - 1}")
        owners = frozenset(a._owned)
        if owners != self.host_live:
            ghosts = sorted(owners - self.host_live)
            naked = sorted(self.host_live - owners)
            if ghosts:
                violations.append(
                    f"{op}: ghost owners {ghosts} (retired but not freed)")
            if naked:
                violations.append(
                    f"{op}: live slots {naked} own no blocks (cache space "
                    "freed under an active request)")
        for s in sorted(self.host_live):
            want = self.lengths.get(s)
            got = len(a.owned_by(s))
            if want is not None and got != want:
                violations.append(
                    f"{op}: slot {s} owns {got} blocks but the scheduler "
                    f"ledger says {want} (reservation drift — a double "
                    "grow or unrecorded shrink)")
        for s in sorted(self.preempted):
            if a.owned_by(s):
                violations.append(
                    f"{op}: preempted slot {s} still owns blocks "
                    f"{a.owned_by(s)} (eviction must release everything)")
        for i in range(1, len(self.ring)):
            if not self.ring[i] <= self.ring[i - 1]:
                violations.append(
                    f"{op}: ring mask grew mid-burst "
                    f"({sorted(self.ring[i - 1])} -> {sorted(self.ring[i])})"
                    " — a consume of the older entry would treat the new "
                    "row as having been device-active before its admission")

    # ----------------------------------------------------------- operations

    def ops(self) -> List[Tuple]:
        """Enabled (op, arg) moves from this state."""
        out: List[Tuple] = []
        admit_ok = (not self.ring) or self.bug == "admit_unsynced"
        if admit_ok:
            for s in range(self.num_slots):
                if s not in self.host_live and s not in self.preempted:
                    out.append(("admit", s))
            for s in sorted(self.preempted):
                out.append(("resume", s))
        if len(self.ring) < self.depth and self.host_live:
            out.append(("dispatch", None))
        if self.ring:
            mask = self.ring[0]
            for fin in _subsets(mask & self.host_live):
                out.append(("consume", frozenset(fin)))
        # On-demand growth appends blocks to a live reservation; it is
        # legal mid-flight (older entries reference a PREFIX of the
        # grown reservation, never the new blocks).
        for s in sorted(self.host_live):
            if self.alloc.free_blocks(0) > 0:
                out.append(("grow", s))
        preempt_ok = (not self.ring) or self.bug == "preempt_in_flight"
        if preempt_ok:
            for s in sorted(self.host_live):
                out.append(("preempt", (s, "reprefill")))
                out.append(("preempt", (s, "swap")))
        # cancel mirrors the engine: it drains the ring before touching
        # device state, so the move only exists on a drained ring.
        if not self.ring:
            for s in sorted(self.host_live):
                out.append(("cancel", s))
        for s in sorted(self.preempted):
            out.append(("expire", s))
        if self.ring:
            for s in sorted(self.ring[0] & self.host_live):
                out.append(("fault_retire", s))
        if not self.ring:
            for s in sorted(self.host_live):
                if len(self.alloc.owned_by(s)) > 1:
                    out.append(("rollback", s))
            if self.alloc.in_use():
                out.append(("defrag", None))
        return out

    def apply(self, op: str, arg, violations: List[str]) -> None:
        a = self.alloc
        if op == "admit":
            ids = a.alloc(arg, 2)
            if ids is None:
                ids = a.alloc(arg, 1)       # backpressure: try smaller
            if ids is not None:
                self.host_live = self.host_live | {arg}
                self.lengths[arg] = len(ids)
        elif op == "grow":
            got = a.grow(arg, 1)
            if got is not None:
                if self.bug == "double_grow":
                    a.grow(arg, 1)          # second alloc, never recorded
                self.lengths[arg] += 1
        elif op == "preempt":
            s, mode = arg
            if any(s in entry for entry in self.ring):
                violations.append(
                    f"preempt: evicting slot {s} while an in-flight step "
                    "still references it — the device can write blocks "
                    "the pool has already handed out")
            freed = a.free(s)
            if not freed:
                violations.append(
                    f"preempt: evicting slot {s} freed NO blocks")
            saved = len(freed)
            self.preempted[s] = (mode, saved)
            self.host_live = self.host_live - {s}
            self.lengths.pop(s, None)
        elif op == "resume":
            mode, saved = self.preempted[arg]
            if mode == "swap":
                # swap restore needs exactly the saved context back
                ids = a.alloc(arg, saved)
                if ids is not None and len(ids) != saved:
                    violations.append(
                        f"resume: swap slot {arg} got {len(ids)} blocks, "
                        f"saved {saved}")
            else:
                ids = a.alloc(arg, 2)
                if ids is None:
                    ids = a.alloc(arg, 1)   # reprefill can shrink its ask
            if ids is not None:
                self.host_live = self.host_live | {arg}
                self.lengths[arg] = len(ids)
                del self.preempted[arg]
        elif op == "dispatch":
            self.ring = self.ring + (self.host_live,)
            if self.bug == "free_on_dispatch" and self.host_live:
                a.free(min(self.host_live))
        elif op == "consume":
            self.ring = self.ring[1:]
            for s in sorted(arg):
                freed = a.free(s)
                if not freed:
                    violations.append(
                        f"consume: retiring slot {s} freed NO blocks "
                        "(double free or free-while-in-flight)")
                if self.bug == "double_free":
                    again = a.free(s)
                    if not again:
                        violations.append(
                            f"consume: second free of slot {s} returned "
                            "nothing — double free detected")
                if self.bug != "leak_on_retire" or not freed:
                    self.host_live = self.host_live - {s}
                else:
                    # leak: slot dropped from live set without the free
                    a._owned[s] = freed
                    for b in freed:
                        a._free[a.home_shard(b)].remove(b)
                    self.host_live = self.host_live - {s}
                self.lengths.pop(s, None)
        elif op == "cancel":
            freed = a.free(arg)
            if not freed:
                violations.append(
                    f"cancel: cancelling slot {arg} freed NO blocks "
                    "(double free, or cancel of an already-retired slot)")
            if self.bug == "cancel_double_free" and freed:
                again = a.free(arg)
                if not again:
                    violations.append(
                        f"cancel: second free of slot {arg} returned "
                        "nothing — cancel raced a retirement into a "
                        "double free")
            self.host_live = self.host_live - {arg}
            self.lengths.pop(arg, None)
        elif op == "expire":
            # Deadline shed of a parked request: host-only retire — its
            # blocks were already released when it was evicted.
            del self.preempted[arg]
        elif op == "fault_retire":
            # Quarantine: the oldest entry is consumed and one poisoned
            # row retires to the parked (reprefill) state for a retry.
            self.ring = self.ring[1:]
            freed = a.free(arg)
            if not freed:
                violations.append(
                    f"fault_retire: quarantining slot {arg} freed NO "
                    "blocks (double free or ghost quarantine)")
            self.host_live = self.host_live - {arg}
            self.lengths.pop(arg, None)
            self.preempted[arg] = ("reprefill", len(freed))
        elif op == "rollback":
            a.release_suffix(arg, 1)
            self.lengths[arg] = 1
        elif op == "defrag":
            before = {k: len(v) for k, v in a._owned.items()}
            moves = a.defrag()
            after = {k: len(v) for k, v in a._owned.items()}
            if before != after:
                violations.append(
                    f"defrag: per-owner block counts changed {before} -> "
                    f"{after} (moves {moves})")
        else:  # pragma: no cover
            raise ValueError(op)
        self.check(op, violations)


def check_interleavings(num_slots: int = 2, num_blocks: int = 4,
                        depth: int = 2, max_ops: int = 7,
                        bug: Optional[str] = None,
                        max_violations: int = 8) -> InterleaveReport:
    """DFS every operation schedule up to ``max_ops`` moves (deduplicating
    revisited states) and collect invariant violations.  With ``bug=None``
    on the real allocator this must come back clean; with a ``BUGS`` entry
    injected it must not."""
    if bug is not None and bug not in BUGS:
        raise ValueError(f"unknown bug {bug!r}; pick from {BUGS}")
    model = _Model(num_slots, num_blocks, depth, bug)
    violations: List[str] = []
    seen = set()
    stats = {"states": 0, "schedules": 0}

    def dfs(depth_left: int) -> None:
        if len(violations) >= max_violations:
            return
        key = model.key()
        if (key, depth_left) in seen:
            return
        seen.add((key, depth_left))
        stats["states"] += 1
        moves = model.ops()
        if depth_left == 0 or not moves:
            stats["schedules"] += 1
            return
        for op, arg in moves:
            saved = model.key()
            n_before = len(violations)
            model.apply(op, arg, violations)
            if len(violations) == n_before:
                dfs(depth_left - 1)
            else:
                stats["schedules"] += 1  # violating branch: stop here
            model.set_key(saved)
            if len(violations) >= max_violations:
                return

    dfs(max_ops)
    return InterleaveReport(
        num_slots=num_slots, num_blocks=num_blocks, depth=depth,
        max_ops=max_ops, states_explored=stats["states"],
        schedules_explored=stats["schedules"],
        violations=violations[:max_violations], bug=bug)


def _dedupe(msgs: List[str]) -> List[str]:
    out: List[str] = []
    for m in msgs:
        if m not in out:
            out.append(m)
    return out


def summarize(report: InterleaveReport) -> Dict:
    return {
        "ok": report.ok,
        "states_explored": report.states_explored,
        "schedules_explored": report.schedules_explored,
        "violations": _dedupe(report.violations),
        "bug": report.bug,
    }
