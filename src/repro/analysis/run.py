"""CLI driver: audit every serving root of a config, print the verdict.

    python -m repro.analysis.run --config llama-7b --reduced --layout both
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        python -m repro.analysis.run --config llama-7b --reduced \\
        --layout paged --dp 2 --tp 2

Exit code 0 iff every audit over every traced root passes: transfer
contract, donation aliasing, sharding pins, dtype lint, Pallas VMEM lint,
and the allocator/ring interleaving check.  Designed to run from CI on CPU
(abstract tracing only — nothing is allocated, no step executes)."""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.analysis.donation import audit_donation
from repro.analysis.dtypes import audit_dtypes, default_upcast_threshold
from repro.analysis.interleave import check_interleavings, summarize
from repro.analysis.pallas_lint import serving_kernel_lints
from repro.analysis.roots import audit_roots
from repro.analysis.sharding_drift import audit_sharding
from repro.analysis.transfers import audit_transfers


def _flag(ok: bool) -> str:
    return "ok " if ok else "FAIL"


def audit_layout(model, params_avals, layout: str, par,
                 *, spec: bool = True, compile: bool = True,
                 **ctx_kw) -> List[Dict]:
    """Run the four per-root audits over every root of one layout."""
    arts = audit_roots(model, params_avals, par=par, layout=layout,
                       spec=spec, compile=compile, **ctx_kw)
    thresh = default_upcast_threshold(params_avals)
    rows: List[Dict] = []
    for art in arts:
        tr = audit_transfers(art)
        dn = audit_donation(art)
        sh = audit_sharding(art)
        dt = audit_dtypes(art, upcast_threshold=thresh)
        # Observability stamp: rebuilding the root via its registry spec is
        # a cheap closure construction; ``repro.obs.profiler.wrap_root``
        # marks every instrumented root with ``__obs_name__``.  The audits
        # above already ran ON the instrumented function (trace_root goes
        # through spec.build too), so a row that passes here certifies the
        # one-D2H / donation / sharding contracts hold WITH telemetry
        # instrumentation in place.
        try:
            instrumented = hasattr(art.spec.build(art.ctx), "__obs_name__")
        except Exception:
            instrumented = False
        rows.append({
            "root": art.name,
            "layout": layout,
            "kind": art.spec.kind,
            "instrumented": instrumented,
            "transfers": {"ok": tr.ok, "d2h_outputs": len(tr.d2h_outputs),
                          "d2h_bytes": tr.d2h_bytes,
                          "problems": tr.notes + tr.host_comm_ops},
            "donation": {"ok": dn.ok, "expected": dn.expected_aliases,
                         "actual": dn.actual_aliases,
                         "missing": dn.missing, "notes": dn.notes},
            "sharding": {"ok": sh.ok, "skipped": sh.skipped,
                         "checked_leaves": sh.checked_leaves,
                         "mismatches": sh.mismatches},
            "dtypes": {"ok": dt.ok, "f64_ops": dt.f64_ops,
                       "large_upcasts": dt.large_upcasts},
            "ok": tr.ok and dn.ok and sh.ok and dt.ok,
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.run",
        description="Static contract auditor for the serving jit roots.")
    ap.add_argument("--config", default="llama-7b",
                    help="model config name (repro.configs registry)")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config (CI-sized tracing)")
    ap.add_argument("--layout", choices=("dense", "paged", "both"),
                    default="both")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--no-spec", action="store_true",
                    help="skip the speculative-decoding roots")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-compile", action="store_true",
                    help="lower only (skips the sharding-drift audit)")
    ap.add_argument("--require-instrumented", action="store_true",
                    help="additionally fail any root whose registry build "
                         "is not wrapped by the observability layer "
                         "(repro.obs.profiler.wrap_root) — certifies the "
                         "contracts were audited on the instrumented "
                         "functions the engine actually dispatches")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also dump the full report to this path")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.api import cache_layout, param_specs

    cfg = get_config(args.config)
    if args.reduced:
        cfg = cfg.reduced()

    par = None
    if args.dp * args.tp > 1:
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel.sharding import make_parallelism

        mesh = make_serving_mesh(args.dp, args.tp)
        par = make_parallelism(mesh)
        print(f"mesh: dp={mesh.shape['data']} tp={mesh.shape['model']} "
              f"({mesh.size} device(s))")

    model = build_model(cfg)
    params_avals = param_specs(cfg)
    native = cache_layout(model)
    layouts = [args.layout] if args.layout != "both" else (
        ["dense", "paged"] if native == "paged" else ["dense"])
    if "paged" in layouts and native != "paged":
        print(f"config {cfg.name}: cache layout {native!r} — "
              "skipping paged roots")
        layouts = [x for x in layouts if x != "paged"]

    report: Dict = {"config": cfg.name, "layouts": {}, "ok": True}
    for layout in layouts:
        rows = audit_layout(
            model, params_avals, layout, par,
            spec=not args.no_spec, compile=not args.no_compile,
            max_batch=args.max_batch, max_len=args.max_len,
            kv_quant=args.kv_quant, spec_k=args.spec_k,
        )
        if args.require_instrumented:
            for r in rows:
                r["ok"] = r["ok"] and r["instrumented"]
        report["layouts"][layout] = rows
        print(f"\n== {cfg.name} [{layout}] "
              f"{'(meshless)' if par is None else ''}")
        for r in rows:
            print(f"  {_flag(r['ok'])} {r['root']:<22} "
                  f"d2h={r['transfers']['d2h_outputs']} "
                  f"alias={r['donation']['actual']}/"
                  f"{r['donation']['expected']} "
                  f"shard={'skip' if r['sharding']['skipped'] else r['sharding']['checked_leaves']} "
                  f"dtype={'ok' if r['dtypes']['ok'] else 'FAIL'} "
                  f"obs={'yes' if r['instrumented'] else 'no'}")
            for sec in ("transfers", "donation", "sharding", "dtypes"):
                for msg in (r[sec].get("problems", [])
                            + r[sec].get("missing", [])
                            + r[sec].get("mismatches", [])
                            + r[sec].get("f64_ops", [])
                            + r[sec].get("large_upcasts", [])):
                    print(f"       {sec}: {msg}")
        report["ok"] &= all(r["ok"] for r in rows)

    # ---- Pallas VMEM lint (layout-independent; geometry from cfg)
    lints = serving_kernel_lints(cfg, max_batch=args.max_batch,
                                 max_len=args.max_len,
                                 kv_quant=args.kv_quant)
    print("\n== pallas vmem lint")
    report["pallas"] = []
    for lint in lints:
        print(f"  {_flag(lint.ok)} {lint.kernel:<18} "
              f"{lint.vmem_bytes / 2**20:6.2f} MiB "
              f"/ {lint.vmem_limit / 2**20:.1f} MiB budget"
              + (f"  ({len(lint.misaligned)} unaligned tiles)"
                 if lint.misaligned else ""))
        report["pallas"].append({
            "kernel": lint.kernel, "ok": lint.ok,
            "vmem_bytes": lint.vmem_bytes,
            "misaligned": lint.misaligned,
        })
        report["ok"] &= lint.ok

    # ---- allocator x ring interleavings (model-level, config-independent)
    inter = summarize(check_interleavings())
    print(f"\n== interleave check: {_flag(inter['ok'])} "
          f"{inter['states_explored']} states, "
          f"{inter['schedules_explored']} schedules")
    for v in inter["violations"]:
        print(f"       {v}")
    report["interleave"] = inter
    report["ok"] &= inter["ok"]

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"\nreport -> {args.json_out}")

    print(f"\n{'ALL CONTRACTS HOLD' if report['ok'] else 'CONTRACT VIOLATIONS'}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
