"""Transfer audit: device<->host traffic per serving root, statically.

Two halves:

  * The lowered stablehlo must contain NO host-communication ops at all —
    no infeed/outfeed, no send/recv, no host callbacks.  Any of these
    inside a decode root would serialize the step pipeline on the host.
  * The only D2H a root may cost is the engine reading back the declared
    ``d2h`` output indices after the call — "steady" roots (the pipelined
    decode loop) must declare EXACTLY one (the sampled-token vector /
    packed spec commit matrix), "draft" roots none, "admission" roots at
    most one (the first-token vector).

The per-step D2H payload bytes are reported so the one-transfer contract
is also a SMALL-transfer contract (a (B,) token vector, not a logits
matrix)."""

from __future__ import annotations

import dataclasses
import re
from typing import List, Tuple

import jax
import numpy as np

# stablehlo host-communication ops + host callbacks via custom_call.
_HOST_COMM_RE = re.compile(
    r"\b(?:stablehlo\.)?(outfeed|infeed|send|recv)\b")
_CALLBACK_RE = re.compile(
    r'call_target_name\s*=\s*"[^"]*(?:callback|host)[^"]*"')


def _aval_bytes(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)
               * np.dtype(aval.dtype).itemsize)


@dataclasses.dataclass
class TransferAudit:
    root: str
    kind: str
    host_comm_ops: List[str]
    d2h_outputs: Tuple[int, ...]
    d2h_bytes: int
    ok: bool
    notes: List[str]


def audit_transfers(art) -> TransferAudit:
    text = art.lowered.as_text()
    comm = [m.group(1) for m in _HOST_COMM_RE.finditer(text)]
    comm += [m.group(0) for m in _CALLBACK_RE.finditer(text)]
    notes: List[str] = []

    outs = list(art.out_avals)
    d2h = art.spec.d2h
    d2h_bytes = sum(
        sum(_aval_bytes(leaf) for leaf in jax.tree.leaves(outs[i]))
        for i in d2h
    )
    kind = art.spec.kind
    ok = not comm
    if comm:
        notes.append(f"host communication ops in lowering: {sorted(set(comm))}")
    if kind == "steady" and len(d2h) != 1:
        ok = False
        notes.append(
            f"steady root declares {len(d2h)} D2H outputs; the pipelined "
            "decode loop contract is exactly one per step"
        )
    if kind == "draft" and d2h:
        ok = False
        notes.append("draft root declares a D2H output; drafts feed the "
                     "verify root on device")
    if kind == "admission" and len(d2h) > 1:
        ok = False
        notes.append(f"admission root declares {len(d2h)} D2H outputs")
    return TransferAudit(root=art.name, kind=kind, host_comm_ops=comm,
                         d2h_outputs=d2h, d2h_bytes=d2h_bytes, ok=ok,
                         notes=notes)
