"""Learning-rate schedules (multiplier form: step -> scale in [0, 1])."""

from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def linear_warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn


def inverse_sqrt(warmup: int):
    def fn(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return jnp.minimum(s / max(warmup, 1), jnp.sqrt(warmup / s))

    return fn
