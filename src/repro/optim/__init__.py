from .adamw import AdamWConfig, AdamWState, apply_updates, global_norm, init_state, state_pspecs, zero_pspec
from .grad import compress_grad, decompress_grad, roundtrip
from .schedule import constant, inverse_sqrt, linear_warmup_cosine
