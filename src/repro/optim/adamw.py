"""AdamW with ZeRO-1 state sharding and optional grad compression hooks.

Design (1000+ node scale, DESIGN.md §5):
  * params live in model dtype (bf16 at scale); the optimizer carries fp32
    master copies + moments.
  * ZeRO-1: master/moments are sharded over the DP axes *in addition to* the
    param's own TP sharding — expressed purely through out_shardings on the
    optimizer state (XLA inserts reduce-scatter/all-gather around the
    update).  ``zero_pspec`` picks the largest TP-free dim.
  * gradient clipping by global norm; optional int8 gradient compression
    with error feedback (repro/optim/grad.py) applied before the DP
    all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # Schedule hook: step -> multiplier (see schedule.py).
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment (fp32)
    nu: Any  # second moment (fp32)
    master: Any  # fp32 master params


def init_state(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(
    params,
    grads,
    state: AdamWState,
    cfg: AdamWConfig,
) -> Tuple[Any, AdamWState, Mapping[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * delta
        return mu, nu, master, master.astype(p.dtype)

    flat_out = jax.tree.map(upd, grads, state.mu, state.nu, state.master, params)
    # Unzip the 4-tuples.
    mu = jax.tree.map(lambda t: t[0], flat_out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], flat_out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], flat_out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda t: t[3], flat_out, is_leaf=lambda x: isinstance(x, tuple))

    new_state = AdamWState(step=step, mu=mu, nu=nu, master=master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


# ------------------------------------------------------------------ ZeRO-1

def zero_pspec(param_spec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...]) -> P:
    """Shard an optimizer-state leaf over the DP axes on its largest dim not
    already claimed by TP.  Falls back to the param spec when no dim is free
    or divisible."""

    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))

    def uses_dp(e):
        if e is None:
            return False
        axes = e if isinstance(e, tuple) else (e,)
        return any(a in dp_axes for a in axes)

    if any(uses_dp(e) for e in entries):
        return param_spec  # FSDP already shards this param over DP
    free = [i for i, e in enumerate(entries) if e is None and shape[i] > 1]
    if not free:
        return param_spec
    target = max(free, key=lambda i: shape[i])
    new_entries = list(entries)
    new_entries[target] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*new_entries)


def state_pspecs(params_shape, param_pspec_tree, dp_axes: Tuple[str, ...]):
    """PartitionSpec tree for AdamWState given the params' spec tree."""

    def zspec(leaf, spec):
        return zero_pspec(spec, leaf.shape, dp_axes)

    moments = jax.tree.map(zspec, params_shape, param_pspec_tree)
    return AdamWState(step=P(), mu=moments, nu=moments, master=moments)
