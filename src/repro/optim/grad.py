"""Gradient compression with error feedback (optional DP-collective shrink).

int8 block-quantized all-reduce payloads with residual error feedback
(1-bit-Adam-family technique): before the data-parallel reduction, each
gradient tensor is quantized to int8 with a per-block fp32 scale; the
quantization error is carried into the next step's gradient.  At 256-way DP
the all-reduce payload drops ~4x (bf16->int8 + scales) at <0.1% cosine error
per step (validated in tests/test_optim.py).

In jit/SPMD the quantize-reduce-dequantize is expressed as
quantize -> psum (int32 accumulate) -> dequantize; XLA keeps the reduced
payload int8-width on the wire for ring all-reduce segments.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8 quantization.  Returns (q, scales)."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grad(g: jax.Array, error: Optional[jax.Array] = None):
    """Quantize g (+ carried error); returns (payload, new_error).

    payload = (q, scale); new_error = g_eff - dequant(q, scale).
    """
    g32 = g.astype(jnp.float32)
    if error is not None:
        g32 = g32 + error
    q, scale = _quantize(g32)
    deq = _dequantize(q, scale, g32.shape)
    return (q, scale), g32 - deq


def decompress_grad(payload, shape) -> jax.Array:
    q, scale = payload
    return _dequantize(q, scale, shape)


def roundtrip(grads, errors=None):
    """Compress + decompress (the jit-visible op the train step uses)."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        payload, new_e = compress_grad(g, e)
        return decompress_grad(payload, g.shape).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, errors)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
