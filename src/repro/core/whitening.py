"""Activation whitening transforms S extracted from calibration Grams.

Every activation-aware method transforms the weight A into A @ S before the
SVD, where S is derived from the calibration activation matrix X (n x p):

  ASVD-0   S = diag(mean_i |x_i|)              (Yuan et al. scaling)
  ASVD-I   S = Cholesky factor of X X^T        (SVD-LLM / Wang et al.)
  ASVD-II  S = P Lambda^{1/2} from X X^T = P Lambda P^T (paper Thm 3)
  ASVD-III S = P * gamma, gamma = max sqrt(eig) (paper Thm 4, failure trial)

We never materialize X: the calibration runner accumulates the Gram
G = X X^T (n x n, fp32/fp64) and the per-channel absolute means in a
streaming fashion (see repro/calib/gram.py).  All factorizations here consume
(G, absmean) only.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class Whitener:
    """Holds S and its (pseudo-)inverse application.

    s:      (n, n) or (n,) diagonal — the transform applied as A @ S.
    s_inv:  matching inverse so that (A S)(S^{-1} X) == A X.
    diagonal: True when s/s_inv are stored as vectors.
    rank:   numerical rank of the Gram (n when full rank).
    """

    s: Array
    s_inv: Array
    diagonal: bool
    rank: int
    method: str

    def apply_right(self, a: Array) -> Array:
        """Compute A @ S."""
        a = np.asarray(a, dtype=self.s.dtype)
        if self.diagonal:
            return a * self.s[None, :]
        return a @ self.s

    def unapply_right(self, b: Array) -> Array:
        """Compute B @ S^{-1} (recover the weight-space factor)."""
        b = np.asarray(b, dtype=self.s_inv.dtype)
        if self.diagonal:
            return b * self.s_inv[None, :]
        return b @ self.s_inv


def _regularize(gram: Array, damp: float) -> Array:
    """Symmetrize + dampen the Gram. `damp` is relative to mean diagonal,
    mirroring GPTQ's percdamp — guards Cholesky against semi-definiteness."""
    g = np.asarray(gram, dtype=np.float64)
    g = 0.5 * (g + g.T)
    if damp > 0.0:
        mean_diag = float(np.mean(np.diag(g)))
        g = g + damp * max(mean_diag, 1e-12) * np.eye(g.shape[0])
    return g


def diag_absmean_whitener(absmean: Array, eps: float = 1e-6) -> Whitener:
    """ASVD-0: per-input-channel |mean| scaling (diagonal)."""
    d = np.asarray(absmean, dtype=np.float64)
    d = np.maximum(d, eps)
    return Whitener(s=d, s_inv=1.0 / d, diagonal=True, rank=d.shape[0], method="asvd0")


def _tri_inv(l: Array) -> Array:
    """Inverse of a lower-triangular matrix via back-substitution (no scipy)."""
    n = l.shape[0]
    inv = np.linalg.solve(l, np.eye(n, dtype=l.dtype))
    return inv


def make_cholesky_whitener(gram: Array, damp: float = 1e-6) -> Whitener:
    """ASVD-I (SVD-LLM): S = lower Cholesky factor of XX^T."""
    g = _regularize(gram, damp)
    try:
        l = np.linalg.cholesky(g)
    except np.linalg.LinAlgError:
        # Rank-deficient even after damping: paper's stated failure mode for
        # the Cholesky path; defer to the eigen (ASVD-II) construction.
        return make_eigen_whitener(gram, damp=damp, method="asvd1_fallback")
    s_inv = _tri_inv(l)
    return Whitener(s=l, s_inv=s_inv, diagonal=False, rank=g.shape[0], method="asvd1")


def make_eigen_whitener(
    gram: Array,
    damp: float = 0.0,
    rank_rtol: float = 1e-10,
    method: str = "asvd2",
) -> Whitener:
    """ASVD-II: S = P Lambda^{1/2} from the eigendecomposition of XX^T.

    Zero eigenvalues are handled with the pseudo-inverse (paper §3: "the
    method via SVD does not require adjustments for zero eigenvalues since
    pseudo-inverses can be applied").
    """
    g = _regularize(gram, damp)
    lam, p = np.linalg.eigh(g)  # ascending
    lam = lam[::-1].copy()
    p = p[:, ::-1].copy()
    lam = np.maximum(lam, 0.0)
    if lam[0] <= 0.0:
        # Degenerate all-zero Gram: identity transform.
        n = g.shape[0]
        return Whitener(np.ones(n), np.ones(n), True, 0, method)
    cutoff = lam[0] * rank_rtol
    rank = int(np.sum(lam > cutoff))
    sqrt_lam = np.sqrt(lam)
    inv_sqrt = np.where(lam > cutoff, 1.0 / np.maximum(sqrt_lam, 1e-300), 0.0)
    s = p * sqrt_lam[None, :]  # P @ diag(sqrt(lam))
    s_inv = inv_sqrt[:, None] * p.T  # diag(pinv sqrt) @ P^T
    return Whitener(s=s, s_inv=s_inv, diagonal=False, rank=rank, method=method)


def make_gamma_whitener(gram: Array, damp: float = 0.0) -> Whitener:
    """ASVD-III (Thm 4): S = P * gamma with gamma = max(Lambda^{1/2}).

    Rotation by P followed by a *scalar* scale; the loss bound is then
    sigma_i^2 * tr(Lambda/gamma^2 v v^T) <= sigma_i^2.  Reported by the paper
    as a failure trial — kept for the ablation benchmark.
    """
    g = _regularize(gram, damp)
    lam, p = np.linalg.eigh(g)
    lam = np.maximum(lam[::-1].copy(), 0.0)
    p = p[:, ::-1].copy()
    gamma = float(np.sqrt(lam[0])) if lam[0] > 0 else 1.0
    s = p * gamma
    s_inv = p.T / gamma
    rank = int(np.sum(lam > lam[0] * 1e-10)) if lam[0] > 0 else 0
    return Whitener(s=s, s_inv=s_inv, diagonal=False, rank=rank, method="asvd3")


def make_whitener(
    method: str,
    gram: Optional[Array] = None,
    absmean: Optional[Array] = None,
    damp: float = 1e-6,
) -> Whitener:
    """Factory keyed by compressor name."""
    m = method.lower()
    if m in ("asvd0", "diag"):
        if absmean is None:
            if gram is None:
                raise ValueError("asvd0 needs absmean or gram")
            absmean = np.sqrt(np.maximum(np.diag(np.asarray(gram, np.float64)), 0.0))
        return diag_absmean_whitener(absmean)
    if gram is None:
        raise ValueError(f"{method} needs a Gram matrix")
    if m in ("asvd1", "cholesky", "svd-llm"):
        return make_cholesky_whitener(gram, damp=damp)
    if m in ("asvd2", "eigen", "svd"):
        return make_eigen_whitener(gram, damp=damp)
    if m in ("asvd3", "gamma"):
        return make_gamma_whitener(gram, damp=damp)
    raise ValueError(f"unknown whitening method {method!r}")
