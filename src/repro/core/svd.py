"""Truncated / randomized SVD primitives used by every compressor.

All factorization math runs on host in float64 by default: compression is an
offline, once-per-checkpoint pass (GPTQ-style), and the theorem-level
exactness tests (loss == sqrt(sum of truncated sigma^2)) only hold to
float64 tolerances.  The *runtime* factors are cast back to the model dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class SVDResult:
    """Thin container for a (possibly truncated) SVD  A ~= U @ diag(s) @ Vt."""

    u: Array  # (m, k)
    s: Array  # (k,)
    vt: Array  # (k, n)

    @property
    def rank(self) -> int:
        return int(self.s.shape[0])

    def truncate(self, k: int) -> "SVDResult":
        k = min(k, self.rank)
        return SVDResult(self.u[:, :k], self.s[:k], self.vt[:k, :])

    def matrix(self) -> Array:
        return (self.u * self.s[None, :]) @ self.vt

    def factors(self, split: str = "sqrt") -> Tuple[Array, Array]:
        """Return (W, Z) with W @ Z == U diag(s) Vt.

        split: 'sqrt'  -> W = U sqrt(s), Z = sqrt(s) Vt  (balanced norms)
               'left'  -> W = U s,       Z = Vt
               'right' -> W = U,         Z = s Vt
        """
        if split == "sqrt":
            rs = np.sqrt(self.s)
            return self.u * rs[None, :], rs[:, None] * self.vt
        if split == "left":
            return self.u * self.s[None, :], self.vt
        if split == "right":
            return self.u, self.s[:, None] * self.vt
        raise ValueError(f"unknown split {split!r}")


def svd(a: Array, full_matrices: bool = False, dtype=np.float64) -> SVDResult:
    """Dense SVD in float64 (host), robust to LAPACK gesdd nonconvergence.

    Fallback: eigendecomposition of the smaller Gram (A^T A or A A^T) —
    always converges for symmetric matrices; accuracy loss ~sqrt(eps) only
    on the smallest singular values, which truncation discards anyway.
    """
    a = np.asarray(a, dtype=dtype)
    try:
        u, s, vt = np.linalg.svd(a, full_matrices=full_matrices)
        return SVDResult(u, s, vt)
    except np.linalg.LinAlgError:
        m, n = a.shape
        if n <= m:
            lam, v = np.linalg.eigh(a.T @ a)
            lam = np.maximum(lam[::-1], 0.0)
            v = v[:, ::-1]
            s = np.sqrt(lam)
            safe = np.maximum(s, 1e-300)
            u = (a @ v) / safe[None, :]
            return SVDResult(u, s, v.T)
        lam, u = np.linalg.eigh(a @ a.T)
        lam = np.maximum(lam[::-1], 0.0)
        u = u[:, ::-1]
        s = np.sqrt(lam)
        safe = np.maximum(s, 1e-300)
        vt = (u.T @ a) / safe[:, None]
        return SVDResult(u, s, vt)


def truncated_svd(a: Array, k: int, dtype=np.float64) -> SVDResult:
    """Best rank-k approximation (Eckart–Young–Mirsky, Thm 1)."""
    return svd(a, dtype=dtype).truncate(k)


def randomized_svd(
    a: Array,
    k: int,
    oversample: int = 16,
    n_iter: int = 4,
    seed: int = 0,
    dtype=np.float64,
) -> SVDResult:
    """Halko–Martinsson–Tropp randomized range finder + small SVD.

    Used for very wide matrices (vocab-sized unembeddings, giant FFNs) where a
    dense SVD of the full matrix is needlessly cubic.  ``n_iter`` power
    iterations sharpen the spectrum estimate; 4 is plenty for the
    fast-decaying spectra of whitened LLM weights.
    """
    a = np.asarray(a, dtype=dtype)
    m, n = a.shape
    ell = min(k + oversample, min(m, n))
    rng = np.random.default_rng(seed)
    omega = rng.standard_normal((n, ell)).astype(dtype)
    y = a @ omega
    # Power iterations with QR re-orthonormalization for stability.
    for _ in range(n_iter):
        y, _ = np.linalg.qr(y)
        y = a @ (a.T @ y)
    q, _ = np.linalg.qr(y)  # (m, ell)
    b = q.T @ a  # (ell, n)
    ub, s, vt = np.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return SVDResult(u[:, :k], s[:k], vt[:k, :])


def best_svd(
    a: Array,
    k: int,
    randomized_threshold: int = 6144,
    dtype=np.float64,
    seed: int = 0,
) -> SVDResult:
    """Dispatch dense vs randomized SVD on matrix size.

    Dense SVD is O(min(m,n)^2 * max(m,n)); for matrices whose small dimension
    exceeds ``randomized_threshold`` and where k is a small fraction of it,
    randomized SVD is an order of magnitude cheaper at negligible accuracy
    cost (validated in tests against the dense oracle).
    """
    m, n = a.shape
    small = min(m, n)
    if small > randomized_threshold and k < small // 4:
        return randomized_svd(a, k, dtype=dtype, seed=seed)
    return truncated_svd(a, k, dtype=dtype)


def frobenius(a: Array) -> float:
    return float(np.linalg.norm(np.asarray(a, dtype=np.float64), "fro"))


def low_rank_storage(m: int, n: int, k: int) -> int:
    """Parameter count of a rank-k factorization of an (m, n) matrix."""
    return (m + n) * k


def max_rank_for_budget(m: int, n: int, budget: int) -> int:
    """Largest k with (m + n) * k <= budget (the fixed-precision dual)."""
    return max(0, budget // (m + n))
