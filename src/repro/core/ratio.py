"""Compression-ratio -> rank budgeting.

Paper convention (following ASVD / SVD-LLM): "compression ratio r" means r of
the original parameters are REMOVED; a rank-k factorization of an (m, n)
matrix stores (m + n) * k parameters, so the per-matrix rank for uniform
ratio r is

    k(m, n, r) = floor((1 - r) * m * n / (m + n)).

Beyond the paper we add:
  * TPU-friendly rounding — ranks rounded to a multiple of `multiple_of`
    (128 aligns the contracted dim of both skinny GEMMs with the MXU;
    rounding direction chosen to respect the global budget).
  * Importance-weighted global allocation — spends a global rank budget
    across matrices proportionally to their truncation-loss tails (the
    sigma_i of A S are exact losses per Thm 2/3), instead of uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence

import numpy as np


def rank_for_ratio(m: int, n: int, ratio: float, multiple_of: int = 1) -> int:
    """Largest rank whose storage is <= (1 - ratio) of the dense matrix."""
    if not 0.0 <= ratio < 1.0:
        raise ValueError(f"ratio must be in [0, 1), got {ratio}")
    budget = (1.0 - ratio) * m * n
    k = int(budget // (m + n))
    k = max(1, k)
    if multiple_of > 1:
        k = _align_rank(k, multiple_of, m, n)
    return k


def _align_rank(k: int, multiple_of: int, m: int, n: int) -> int:
    """Round a budget-respecting rank onto the alignment grid.

    Rounds DOWN so the aligned rank never stores more than the unaligned
    one (the caller's budget).  The single exception is k < multiple_of,
    where the floor would be rank zero: we return one ``multiple_of``
    (the documented minimum) even though it may exceed the budget.
    Always capped at the rank where factorization stops compressing.
    """
    down = (k // multiple_of) * multiple_of
    k = down if down >= multiple_of else multiple_of
    return min(k, max(1, (m * n) // (m + n)))


def ratio_for_rank(m: int, n: int, k: int) -> float:
    """Fraction of parameters removed by a rank-k factorization."""
    return 1.0 - (m + n) * k / (m * n)


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """A compressible matrix: its shape and the Gram it whitens against."""

    name: str
    m: int  # output dim (rows of A in paper orientation)
    n: int  # input dim (cols of A; Gram is (n, n))
    gram_key: str
    count: int = 1  # replication (e.g. stacked scan layers share a spec)

    @property
    def dense_params(self) -> int:
        return self.m * self.n * self.count


def uniform_ranks(
    specs: Sequence[MatrixSpec], ratio: float, multiple_of: int = 1
) -> Dict[str, int]:
    """Paper's allocation: every matrix compressed at the same ratio."""
    return {s.name: rank_for_ratio(s.m, s.n, ratio, multiple_of) for s in specs}


def importance_ranks(
    specs: Sequence[MatrixSpec],
    ratio: float,
    tail_losses: Mapping[str, np.ndarray],
    multiple_of: int = 1,
    floor_frac: float = 0.25,
) -> Dict[str, int]:
    """Beyond-paper global allocation using exact per-direction losses.

    ``tail_losses[name]`` are the singular values of A S (descending) — by
    Thm 2/3 sigma_i is exactly the loss of dropping direction i.  We start
    every matrix at ``floor_frac`` of its uniform rank and greedily spend the
    remaining global parameter budget on the directions with the largest
    loss-per-parameter sigma_i^2 / (m + n).
    """
    budget = int(sum((1.0 - ratio) * s.dense_params for s in specs))
    ranks: Dict[str, int] = {}
    spent = 0
    heap: list[tuple[float, int, str, int]] = []  # (-gain, next_i, name, m+n)
    import heapq

    by_name = {s.name: s for s in specs}
    for s in specs:
        k0 = max(1, int(rank_for_ratio(s.m, s.n, ratio) * floor_frac))
        ranks[s.name] = k0
        spent += (s.m + s.n) * k0 * s.count
        sig = np.asarray(tail_losses[s.name], dtype=np.float64)
        if k0 < sig.shape[0]:
            gain = float(sig[k0] ** 2) / (s.m + s.n)
            heapq.heappush(heap, (-gain, k0, s.name, (s.m + s.n) * s.count))
    while heap:
        neg_gain, i, name, cost = heapq.heappop(heap)
        if spent + cost > budget:
            continue
        spent += cost
        ranks[name] = i + 1
        sig = np.asarray(tail_losses[name], dtype=np.float64)
        s = by_name[name]
        if i + 1 < sig.shape[0] and i + 1 < (s.m * s.n) // (s.m + s.n):
            gain = float(sig[i + 1] ** 2) / (s.m + s.n)
            heapq.heappush(heap, (-gain, i + 1, name, cost))
    if multiple_of > 1:
        for name in ranks:
            s = by_name[name]
            ranks[name] = _align_rank(ranks[name], multiple_of, s.m, s.n)
    return ranks


def achieved_ratio(specs: Sequence[MatrixSpec], ranks: Mapping[str, int]) -> float:
    """Realized parameter-removal fraction for a rank assignment."""
    dense = sum(s.dense_params for s in specs)
    comp = sum((s.m + s.n) * ranks[s.name] * s.count for s in specs)
    return 1.0 - comp / dense
