"""Compression plans: which matrices get factored, at what rank, how.

A model definition exposes ``compressible_matrices(cfg) -> list[TargetSpec]``
describing every weight it is willing to factorize: the pytree path of the
{"kernel": ...} leaf, its logical (in, out) shape, how many stacked copies the
leaf holds (scan-over-layers models stack an (L, in, out) kernel), and the
Gram key whose activations whiten it.  ``build_plan`` turns those specs plus a
CompressionConfig into concrete per-matrix ranks.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

import numpy as np

from .ratio import (
    MatrixSpec,
    achieved_ratio,
    importance_ranks,
    rank_for_ratio,
    ratio_for_rank,
    uniform_ranks,
)


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    """One compressible kernel leaf in the param pytree.

    ``stacked`` holds the leading batch dims of the kernel leaf:
      ()        plain (in, out) kernel
      (L,)      scan-over-layers stack
      (L, E)    scanned MoE expert stack (layers x experts)
    Per-slice Grams are looked up as f"{gram_key}/{i0}/{i1}/..." with the
    shared ``gram_key`` as fallback.
    """

    path: Tuple[str, ...]  # pytree path to the dict holding "kernel"
    in_dim: int
    out_dim: int
    gram_key: str
    stacked: Tuple[int, ...] = ()
    per_layer_gram: bool = True  # look up per-slice gram keys first

    @property
    def name(self) -> str:
        return "/".join(self.path)

    @property
    def count(self) -> int:
        c = 1
        for s in self.stacked:
            c *= s
        return c

    def matrix_spec(self) -> MatrixSpec:
        # Paper orientation: A is (out, in) => m = out_dim, n = in_dim.
        return MatrixSpec(
            name=self.name,
            m=self.out_dim,
            n=self.in_dim,
            gram_key=self.gram_key,
            count=self.count,
        )


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """User-facing knobs (paper defaults)."""

    method: str = "nsvd1"  # svd|asvd0|asvd1|asvd2|asvd3|nsvd1|nsvd2|nid1|nid2
    ratio: float = 0.3  # fraction of params removed
    k1_frac: float = 0.95  # nested split (Table 3 sweeps this)
    allocation: str = "uniform"  # uniform | importance (beyond-paper)
    multiple_of: int = 1  # 128 for MXU-aligned deployment ranks
    damp: float = 1e-6
    use_randomized: bool = True
    min_dim: int = 8  # skip tiny matrices (norm scales, routers)
    dtype: str = "bfloat16"


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    config: CompressionConfig
    targets: Tuple[TargetSpec, ...]
    ranks: Mapping[str, int]  # per TargetSpec.name

    @property
    def achieved_ratio(self) -> float:
        return achieved_ratio([t.matrix_spec() for t in self.targets], self.ranks)

    def rank_of(self, spec: TargetSpec) -> int:
        return self.ranks[spec.name]

    def target_rows(self) -> list:
        """Structured per-target summary: the assigned rank next to the
        unaligned budget rank for the requested ratio, and the per-target
        achieved ratio with its delta against the request — so a plan
        artifact is self-describing about where alignment/min-rank
        rounding spent or saved budget."""
        rows = []
        for t in self.targets:
            k = self.ranks[t.name]
            m, n = t.out_dim, t.in_dim
            requested = rank_for_ratio(m, n, self.config.ratio)
            ach = ratio_for_rank(m, n, k)
            rows.append({
                "target": t.name,
                "shape": [m, n],
                "stacked": list(t.stacked),
                "rank": int(k),
                "requested_rank": int(requested),
                "achieved_ratio": ach,
                "ratio_delta": ach - self.config.ratio,
            })
        return rows

    def summary(self) -> str:
        lines = [
            f"method={self.config.method} ratio={self.config.ratio} "
            f"k1_frac={self.config.k1_frac} "
            f"achieved_ratio={self.achieved_ratio:.4f} "
            f"(delta {self.achieved_ratio - self.config.ratio:+.4f})"
        ]
        for r in self.target_rows():
            stack = "x".join(str(s) for s in r["stacked"])
            m, n = r["shape"]
            req = ""
            if r["rank"] != r["requested_rank"]:
                req = f" (requested {r['requested_rank']})"
            lines.append(
                f"  {r['target']}: ({m}x{n})"
                f"{'x' + stack if stack else ''} -> rank {r['rank']}{req}"
                f" ratio={r['achieved_ratio']:.4f}"
                f" (delta {r['ratio_delta']:+.4f})"
            )
        return "\n".join(lines)


def build_plan(
    targets: Sequence[TargetSpec],
    config: CompressionConfig,
    tail_losses: Optional[Mapping[str, np.ndarray]] = None,
) -> CompressionPlan:
    """Assign ranks.  ``tail_losses`` enables the importance allocator."""
    targets = tuple(
        t for t in targets if min(t.in_dim, t.out_dim) >= config.min_dim
    )
    specs = [t.matrix_spec() for t in targets]
    if config.allocation == "uniform" or tail_losses is None:
        ranks = uniform_ranks(specs, config.ratio, config.multiple_of)
    elif config.allocation == "importance":
        ranks = importance_ranks(
            specs, config.ratio, tail_losses, multiple_of=config.multiple_of
        )
    else:
        raise ValueError(f"unknown allocation {config.allocation!r}")
    return CompressionPlan(config=config, targets=targets, ranks=ranks)
