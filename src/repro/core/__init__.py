"""NSVD core: the paper's contribution as a composable library.

Public API:
  - svd: truncated_svd, randomized_svd, best_svd
  - whitening: make_whitener (ASVD-0/I/II/III transforms)
  - asvd: compress (single factorization), activation_loss, gram_loss
  - nsvd: nested_compress (NSVD-I/II, NID-I/II), split_rank, ALL_METHODS
  - ratio: rank_for_ratio, uniform_ranks, importance_ranks
  - lowrank: linear_apply (runtime), factors_to_params
  - plan/compress: build_plan, compress_model, GramStore
"""

from .asvd import LowRankFactors, activation_loss, asvd_compress, compress, gram_loss
from .compress import GramStore, compress_matrix, compress_model, compress_params
from .lowrank import (
    dense_equivalent,
    factors_to_params,
    flops_per_token,
    is_lowrank,
    is_nested,
    linear_apply,
)
from .nid import column_id, id_compress
from .nsvd import ALL_METHODS, NESTED_METHODS, nested_compress, nsvd_compress, split_rank
from .plan import CompressionConfig, CompressionPlan, TargetSpec, build_plan
from .ratio import (
    MatrixSpec,
    achieved_ratio,
    importance_ranks,
    rank_for_ratio,
    ratio_for_rank,
    uniform_ranks,
)
from .svd import SVDResult, best_svd, randomized_svd, truncated_svd
from .whitening import Whitener, make_whitener

# ``from .compress import ...`` binds the *submodule* to the name
# ``compress`` on this package, shadowing asvd.compress — rebind explicitly.
from .asvd import compress as compress  # noqa: F811
