"""Runtime low-rank linear application (JAX side).

A dense linear stores params {"kernel": (in, out)} and computes y = x @ kernel.
After compression the same call site consumes either

    {"u": (in, k), "v": (k, out)}                        (single factorization)
    {"u": (in, k1), "v": (k1, out),
     "u2": (in, k2), "v2": (k2, out)}                    (nested, paper Eq. 6)

and computes y = (x @ u) @ v [+ (x @ u2) @ v2].  The nested form is the
paper's O = W1(Z1 x) + W2(Z2 x) transposed into row-vector convention
(u = Z^T, v = W^T).

``linear_apply`` is the single entry point used by every model layer, so the
whole zoo transparently runs dense or compressed.  Nested matmuls dispatch
through ``kernels.nested_lowrank.ops``: the fused Pallas kernel on TPU for
decode-shaped inputs, the jnp oracle on CPU (which is also what the dry-run
lowers); ``use_kernel`` overrides the choice in either direction.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .asvd import LowRankFactors


def is_lowrank(params: Mapping[str, Any]) -> bool:
    return "u" in params


def is_nested(params: Mapping[str, Any]) -> bool:
    return "u2" in params


def linear_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    use_kernel: Optional[bool] = None,
    precision=None,
) -> jax.Array:
    """y = x @ W for dense, factored, or nested-factored params.

    x: (..., in) -> (..., out).  Factor matmuls contract in the order that
    keeps the intermediate at rank width (never materializes the dense
    kernel).

    Nested params route through ``kernels.nested_lowrank.ops`` by default,
    which picks the fused Pallas kernel for decode-shaped inputs on TPU and
    the jnp oracle everywhere else; ``use_kernel=False`` forces the plain
    jnp path (needed when ``precision`` must be honored), ``True`` forces
    the kernel.
    """
    if "kernel" in params:
        return jnp.matmul(x, params["kernel"], precision=precision)
    if "u" not in params:
        raise KeyError(f"linear params must have 'kernel' or 'u', got {list(params)}")
    if use_kernel is not False and "u2" in params:
        from repro.kernels.nested_lowrank import ops as nlr_ops

        return nlr_ops.nested_lowrank_matmul(
            x, params["u"], params["v"], params["u2"], params["v2"],
            use_kernel=use_kernel,
        )
    y = jnp.matmul(jnp.matmul(x, params["u"], precision=precision), params["v"],
                   precision=precision)
    if "u2" in params:
        y = y + jnp.matmul(
            jnp.matmul(x, params["u2"], precision=precision), params["v2"],
            precision=precision,
        )
    return y


def dense_equivalent(params: Mapping[str, Any]) -> jax.Array:
    """Materialize the (in, out) kernel a factored param represents."""
    if "kernel" in params:
        return params["kernel"]
    k = jnp.matmul(params["u"], params["v"])
    if "u2" in params:
        k = k + jnp.matmul(params["u2"], params["v2"])
    return k


def factors_to_params(factors: LowRankFactors, dtype=jnp.bfloat16) -> dict:
    """Convert paper-orientation factors (A ~= W Z, A = kernel^T) into the
    runtime {"u","v"[,"u2","v2"]} pytree.

    kernel = A^T = Z^T W^T, so u = Z^T (in, k) and v = W^T (k, out).
    """
    out = {
        "u": jnp.asarray(np.ascontiguousarray(factors.z.T), dtype=dtype),
        "v": jnp.asarray(np.ascontiguousarray(factors.w.T), dtype=dtype),
    }
    if factors.nested:
        out["u2"] = jnp.asarray(np.ascontiguousarray(factors.z2.T), dtype=dtype)
        out["v2"] = jnp.asarray(np.ascontiguousarray(factors.w2.T), dtype=dtype)
    return out


def param_count(params: Mapping[str, Any]) -> int:
    return int(sum(np.prod(v.shape) for v in params.values()))


def flops_per_token(params: Mapping[str, Any]) -> int:
    """Forward multiply-accumulate FLOPs (x2) per input row."""
    if "kernel" in params:
        i, o = params["kernel"].shape[-2:]
        return 2 * i * o
    total = 0
    for a, b in (("u", "v"), ("u2", "v2")):
        if a in params:
            i, k = params[a].shape[-2:]
            _, o = params[b].shape[-2:]
            total += 2 * (i * k + k * o)
    return total
