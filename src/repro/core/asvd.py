"""Activation-aware SVD compressors: SVD, ASVD-0, ASVD-I, ASVD-II, ASVD-III.

Each compressor maps (A, calibration stats, rank k) -> (W, Z) with
A ~= W @ Z, rank(W) = rank(Z) = k, minimizing (or sub-optimally bounding)
the activation-weighted loss ||(A - WZ) X||_F per the paper's Theorems 1-4.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .svd import SVDResult, best_svd, truncated_svd
from .whitening import Whitener, make_whitener

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class LowRankFactors:
    """A ~= w @ z  (w: (m, k), z: (k, n)); optionally a nested second pair."""

    w: Array
    z: Array
    w2: Optional[Array] = None
    z2: Optional[Array] = None
    method: str = "svd"

    @property
    def rank(self) -> int:
        k = int(self.w.shape[1])
        if self.w2 is not None:
            k += int(self.w2.shape[1])
        return k

    @property
    def nested(self) -> bool:
        return self.w2 is not None

    def matrix(self) -> Array:
        a = self.w @ self.z
        if self.nested:
            a = a + self.w2 @ self.z2
        return a

    def param_count(self) -> int:
        n = self.w.size + self.z.size
        if self.nested:
            n += self.w2.size + self.z2.size
        return int(n)

    def astype(self, dtype) -> "LowRankFactors":
        return LowRankFactors(
            self.w.astype(dtype),
            self.z.astype(dtype),
            None if self.w2 is None else self.w2.astype(dtype),
            None if self.z2 is None else self.z2.astype(dtype),
            self.method,
        )


def plain_svd_compress(a: Array, k: int, use_randomized: bool = True) -> LowRankFactors:
    """Standard SVD baseline (activation-unaware, Thm 1)."""
    res = best_svd(a, k) if use_randomized else truncated_svd(a, k)
    w, z = res.factors("sqrt")
    return LowRankFactors(w, z, method="svd")


def asvd_compress(
    a: Array,
    k: int,
    whitener: Whitener,
    use_randomized: bool = True,
) -> Tuple[LowRankFactors, SVDResult]:
    """Shared ASVD machinery: SVD(A S), truncate to k, unwhiten the right factor.

    Returns the factors plus the (truncated) SVD of A S — the singular values
    are the *exact* per-direction activation losses for ASVD-I/II (Thms 2/3),
    which the rank allocator uses to budget ranks across layers.
    """
    a = np.asarray(a, dtype=np.float64)
    aw = whitener.apply_right(a)
    res = best_svd(aw, k) if use_randomized else truncated_svd(aw, k)
    # W = U sqrt(s) stays; Z = sqrt(s) V^T S^{-1} returns to weight space.
    w, z_whit = res.factors("sqrt")
    z = whitener.unapply_right(z_whit)  # (k, n) @ (n, n) -> (k, n)
    return LowRankFactors(w, z, method=whitener.method), res


def compress(
    a: Array,
    k: int,
    method: str = "asvd2",
    gram: Optional[Array] = None,
    absmean: Optional[Array] = None,
    damp: float = 1e-6,
    use_randomized: bool = True,
) -> LowRankFactors:
    """One-call façade for the non-nested methods."""
    m = method.lower()
    if m in ("svd", "plain"):
        return plain_svd_compress(a, k, use_randomized)
    whit = make_whitener(m, gram=gram, absmean=absmean, damp=damp)
    factors, _ = asvd_compress(a, k, whit, use_randomized)
    return factors


def activation_loss(a: Array, approx: Array, x: Array) -> float:
    """||(A - approx) X||_F — the quantity Theorems 2-4 bound."""
    d = (np.asarray(a, np.float64) - np.asarray(approx, np.float64)) @ np.asarray(
        x, np.float64
    )
    return float(np.linalg.norm(d, "fro"))


def gram_loss(a: Array, approx: Array, gram: Array) -> float:
    """sqrt(tr((A-B) G (A-B)^T)) == ||(A-B)X||_F computed from the Gram only."""
    d = np.asarray(a, np.float64) - np.asarray(approx, np.float64)
    val = float(np.einsum("ij,jk,ik->", d, np.asarray(gram, np.float64), d))
    return float(np.sqrt(max(val, 0.0)))
