"""Compression orchestrator: dense param pytree -> factored param pytree.

Runs on host (numpy/float64) per matrix, GPTQ-style.  Handles stacked
(scan-over-layers) kernels by compressing each slice against its per-layer
Gram with a shared rank, producing stacked factors that keep the model
scannable.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Dict, Mapping, MutableMapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .lowrank import factors_to_params
from .nsvd import nested_compress
from .plan import CompressionConfig, CompressionPlan, build_plan

logger = logging.getLogger(__name__)


class GramStore:
    """name -> (gram (n,n) fp64, absmean (n,), token_count).

    Filled by the calibration runner; consumed here.  ``fallback`` lets
    per-expert grams defer to the shared layer gram when an expert saw too
    few tokens for a well-conditioned Gram (DESIGN.md §7).
    """

    def __init__(self):
        self._grams: Dict[str, np.ndarray] = {}
        self._absmean: Dict[str, np.ndarray] = {}
        self._counts: Dict[str, float] = {}

    def update(self, key: str, gram: np.ndarray, absmean: np.ndarray, count: float):
        if key in self._grams:
            self._grams[key] = self._grams[key] + gram
            self._absmean[key] = self._absmean[key] + absmean
            self._counts[key] += count
        else:
            self._grams[key] = np.asarray(gram, np.float64).copy()
            self._absmean[key] = np.asarray(absmean, np.float64).copy()
            self._counts[key] = float(count)

    def gram(self, key: str, fallback: Optional[str] = None, min_count: int = 0) -> np.ndarray:
        if key in self._grams and self._counts[key] >= min_count:
            return self._grams[key]
        if fallback is not None and fallback in self._grams:
            return self._grams[fallback]
        raise KeyError(f"no Gram for {key!r} (fallback={fallback!r})")

    def absmean(self, key: str, fallback: Optional[str] = None, min_count: int = 0) -> np.ndarray:
        # Same fallback decision as gram(): a whitening Gram and its absmean
        # must come from the SAME statistics, otherwise the stacked path
        # whitens with the layer Gram while scaling with a per-expert mean.
        if key in self._absmean and self._counts[key] >= min_count:
            k = key
        elif fallback is not None and fallback in self._absmean:
            k = fallback
        else:
            raise KeyError(f"no absmean for {key!r} (fallback={fallback!r})")
        c = max(self._counts[k], 1.0)
        return self._absmean[k] / c

    def count(self, key: str) -> float:
        return self._counts.get(key, 0.0)

    def keys(self):
        return self._grams.keys()

    def save(self, path: str):
        np.savez_compressed(
            path,
            **{f"g::{k}": v for k, v in self._grams.items()},
            **{f"a::{k}": v for k, v in self._absmean.items()},
            **{f"c::{k}": np.asarray(v) for k, v in self._counts.items()},
        )

    @classmethod
    def load(cls, path: str) -> "GramStore":
        store = cls()
        data = np.load(path)
        names = {k[3:] for k in data.files if k.startswith("g::")}
        for name in names:
            store._grams[name] = data[f"g::{name}"]
            store._absmean[name] = data[f"a::{name}"]
            store._counts[name] = float(data[f"c::{name}"])
        return store


def _get_subtree(tree: MutableMapping, path: Tuple[str, ...]):
    node = tree
    for p in path:
        node = node[p]
    return node


def _set_subtree(tree: MutableMapping, path: Tuple[str, ...], value):
    node = tree
    for p in path[:-1]:
        node = node[p]
    node[path[-1]] = value


def compress_matrix(
    kernel: np.ndarray,
    rank: int,
    config: CompressionConfig,
    gram: Optional[np.ndarray],
    absmean: Optional[np.ndarray],
) -> Dict[str, Any]:
    """Compress one (in, out) kernel -> factored params dict (numpy)."""
    a = np.asarray(kernel, np.float64).T  # paper orientation (out, in)
    factors = nested_compress(
        a,
        rank,
        config.method,
        gram=gram,
        absmean=absmean,
        k1_frac=config.k1_frac,
        damp=config.damp,
        use_randomized=config.use_randomized,
    )
    return factors_to_params(factors, dtype=getattr(jnp, config.dtype))


def compress_params(
    params: Mapping[str, Any],
    plan: CompressionPlan,
    grams: GramStore,
) -> Dict[str, Any]:
    """Produce a new param pytree with every planned target factored.

    Non-target leaves are passed through by reference.  Stacked kernels
    (L, in, out) are compressed slice-by-slice against f"{gram_key}/{i}".
    """
    import copy

    new_params = copy.deepcopy(_to_mutable(params))
    cfg = plan.config
    needs_gram = cfg.method not in ("svd", "plain")
    for spec in plan.targets:
        t0 = time.time()
        leaf = _get_subtree(new_params, spec.path)
        if "kernel" not in leaf:
            raise KeyError(f"target {spec.name} has no dense kernel (already compressed?)")
        kernel = np.asarray(leaf["kernel"], np.float32)
        rank = plan.rank_of(spec)
        if spec.stacked:
            flat = kernel.reshape(-1, spec.in_dim, spec.out_dim)
            outs = []
            for flat_i, idx in enumerate(np.ndindex(*spec.stacked)):
                g = a = None
                if needs_gram:
                    suffix = "/".join(str(i) for i in idx)
                    key = (
                        f"{spec.gram_key}/{suffix}"
                        if spec.per_layer_gram
                        else spec.gram_key
                    )
                    min_count = spec.in_dim // 4
                    g = grams.gram(key, fallback=spec.gram_key, min_count=min_count)
                    a = grams.absmean(key, fallback=spec.gram_key, min_count=min_count)
                outs.append(compress_matrix(flat[flat_i], rank, cfg, g, a))
            factored = {
                k: jnp.stack([o[k] for o in outs]).reshape(
                    *spec.stacked, *outs[0][k].shape
                )
                for k in outs[0]
            }
        else:
            g = a = None
            if needs_gram:
                g = grams.gram(spec.gram_key)
                a = grams.absmean(spec.gram_key)
            factored = compress_matrix(kernel, rank, cfg, g, a)
        _set_subtree(new_params, spec.path, factored)
        logger.info("compressed %s rank=%d in %.2fs", spec.name, rank, time.time() - t0)
    return new_params


def _to_mutable(tree):
    if isinstance(tree, Mapping):
        return {k: _to_mutable(v) for k, v in tree.items()}
    return tree


def compress_model(
    params: Mapping[str, Any],
    targets,
    grams: GramStore,
    config: CompressionConfig,
) -> Tuple[Dict[str, Any], CompressionPlan]:
    """Plan + execute in one call (the public API used by examples)."""
    plan = build_plan(targets, config)
    return compress_params(params, plan, grams), plan
