"""Compression orchestrator: dense param pytree -> factored param pytree.

Runs on host (numpy/float64) per matrix, GPTQ-style.  Handles stacked
(scan-over-layers) kernels by compressing each slice against its per-layer
Gram with a shared rank, producing stacked factors that keep the model
scannable.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Dict, Mapping, MutableMapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .lowrank import factors_to_params
from .nsvd import decomposition_diagnostics, nested_compress
from .plan import CompressionConfig, CompressionPlan, build_plan
from .ratio import rank_for_ratio

logger = logging.getLogger(__name__)

# GramStore on-disk schema.  1 = the original unstamped npz layout; 2 adds
# the "__schema__" stamp so future layout changes can migrate instead of
# silently misreading arrays.  Bump when the array layout changes.
GRAM_STORE_SCHEMA = 2


class GramStore:
    """name -> (gram (n,n) fp64, absmean (n,), token_count).

    Filled by the calibration runner; consumed here.  ``fallback`` lets
    per-expert grams defer to the shared layer gram when an expert saw too
    few tokens for a well-conditioned Gram (DESIGN.md §7).
    """

    def __init__(self):
        self._grams: Dict[str, np.ndarray] = {}
        self._absmean: Dict[str, np.ndarray] = {}
        self._counts: Dict[str, float] = {}

    def update(self, key: str, gram: np.ndarray, absmean: np.ndarray, count: float):
        if key in self._grams:
            self._grams[key] = self._grams[key] + gram
            self._absmean[key] = self._absmean[key] + absmean
            self._counts[key] += count
        else:
            self._grams[key] = np.asarray(gram, np.float64).copy()
            self._absmean[key] = np.asarray(absmean, np.float64).copy()
            self._counts[key] = float(count)

    def gram(self, key: str, fallback: Optional[str] = None, min_count: int = 0) -> np.ndarray:
        if key in self._grams and self._counts[key] >= min_count:
            return self._grams[key]
        if fallback is not None and fallback in self._grams:
            return self._grams[fallback]
        raise KeyError(f"no Gram for {key!r} (fallback={fallback!r})")

    def absmean(self, key: str, fallback: Optional[str] = None, min_count: int = 0) -> np.ndarray:
        # Same fallback decision as gram(): a whitening Gram and its absmean
        # must come from the SAME statistics, otherwise the stacked path
        # whitens with the layer Gram while scaling with a per-expert mean.
        if key in self._absmean and self._counts[key] >= min_count:
            k = key
        elif fallback is not None and fallback in self._absmean:
            k = fallback
        else:
            raise KeyError(f"no absmean for {key!r} (fallback={fallback!r})")
        c = max(self._counts[k], 1.0)
        return self._absmean[k] / c

    def count(self, key: str) -> float:
        return self._counts.get(key, 0.0)

    def resolve(
        self, key: str, fallback: Optional[str] = None, min_count: int = 0
    ) -> Tuple[str, Optional[str]]:
        """Which key ``gram()``/``absmean()`` would actually read, plus the
        fallback reason (None when the primary key is used, else
        "missing" or "min_count").  Pure lookup — telemetry uses it to
        count fallback usage without duplicating the decision logic."""
        if key in self._grams:
            if self._counts[key] >= min_count:
                return key, None
            reason = "min_count"
        else:
            reason = "missing"
        if fallback is not None and fallback in self._grams:
            return fallback, reason
        raise KeyError(f"no Gram for {key!r} (fallback={fallback!r})")

    def keys(self):
        return self._grams.keys()

    def save(self, path: str):
        np.savez_compressed(
            path,
            __schema__=np.asarray(GRAM_STORE_SCHEMA),
            **{f"g::{k}": v for k, v in self._grams.items()},
            **{f"a::{k}": v for k, v in self._absmean.items()},
            **{f"c::{k}": np.asarray(v) for k, v in self._counts.items()},
        )

    @classmethod
    def load(cls, path: str) -> "GramStore":
        store = cls()
        data = np.load(path)
        # Unstamped files are the legacy schema-1 layout (same arrays, no
        # version key) and migrate transparently; anything newer than this
        # build understands is rejected instead of misread.
        schema = int(data["__schema__"]) if "__schema__" in data.files else 1
        if not 1 <= schema <= GRAM_STORE_SCHEMA:
            raise ValueError(
                f"GramStore file {path!r} has schema {schema}; this build "
                f"reads schemas 1..{GRAM_STORE_SCHEMA} — refusing to "
                "misinterpret the arrays")
        names = {k[3:] for k in data.files if k.startswith("g::")}
        for name in names:
            if f"a::{name}" not in data.files or f"c::{name}" not in data.files:
                raise ValueError(
                    f"GramStore file {path!r} is corrupt: key {name!r} is "
                    "missing its absmean/count arrays")
            gram = np.asarray(data[f"g::{name}"])
            absmean = np.asarray(data[f"a::{name}"])
            if gram.ndim != 2 or gram.shape[0] != gram.shape[1] \
                    or absmean.shape != gram.shape[:1]:
                raise ValueError(
                    f"GramStore file {path!r} is corrupt: key {name!r} has "
                    f"gram {gram.shape} / absmean {absmean.shape}")
            store._grams[name] = gram
            store._absmean[name] = absmean
            store._counts[name] = float(data[f"c::{name}"])
        return store


def _get_subtree(tree: MutableMapping, path: Tuple[str, ...]):
    node = tree
    for p in path:
        node = node[p]
    return node


def _set_subtree(tree: MutableMapping, path: Tuple[str, ...], value):
    node = tree
    for p in path[:-1]:
        node = node[p]
    node[path[-1]] = value


def compress_matrix(
    kernel: np.ndarray,
    rank: int,
    config: CompressionConfig,
    gram: Optional[np.ndarray],
    absmean: Optional[np.ndarray],
    telemetry: Optional[Any] = None,
    target: str = "",
    slice_idx: Tuple[int, ...] = (),
) -> Dict[str, Any]:
    """Compress one (in, out) kernel -> factored params dict (numpy).

    ``telemetry`` (a ``repro.obs.compression.CompressionTelemetry``, duck-
    typed so core never imports obs) is a pure observer: when enabled it
    records per-slice decomposition diagnostics computed AFTER the factors
    exist, so the factored params are bit-identical with it on or off."""
    a = np.asarray(kernel, np.float64).T  # paper orientation (out, in)
    factors = nested_compress(
        a,
        rank,
        config.method,
        gram=gram,
        absmean=absmean,
        k1_frac=config.k1_frac,
        damp=config.damp,
        use_randomized=config.use_randomized,
    )
    if telemetry is not None and telemetry.enabled:
        telemetry.on_slice(
            target, slice_idx,
            decomposition_diagnostics(
                a, factors, gram=gram,
                compare_plain=getattr(telemetry, "compare_plain", True),
                use_randomized=config.use_randomized,
            ),
        )
    return factors_to_params(factors, dtype=getattr(jnp, config.dtype))


def compress_params(
    params: Mapping[str, Any],
    plan: CompressionPlan,
    grams: GramStore,
    telemetry: Optional[Any] = None,
) -> Dict[str, Any]:
    """Produce a new param pytree with every planned target factored.

    Non-target leaves are passed through by reference.  Stacked kernels
    (L, in, out) are compressed slice-by-slice against f"{gram_key}/{i}".

    ``telemetry`` (``repro.obs.compression.CompressionTelemetry``) observes
    the pass without affecting it: one ``DecompositionReport`` per target
    (plain vs whitened error, tail mass, k1/k2, outlier absorption,
    achieved-vs-requested rank/bytes, Gram fallback usage).  Compressed
    params are bit-identical with telemetry on or off.
    """
    import copy

    new_params = copy.deepcopy(_to_mutable(params))
    cfg = plan.config
    needs_gram = cfg.method not in ("svd", "plain")
    observing = telemetry is not None and telemetry.enabled
    for spec in plan.targets:
        t0 = time.time()
        leaf = _get_subtree(new_params, spec.path)
        if "kernel" not in leaf:
            raise KeyError(f"target {spec.name} has no dense kernel (already compressed?)")
        kernel = np.asarray(leaf["kernel"], np.float32)
        rank = plan.rank_of(spec)
        fallback_slices = 0
        if spec.stacked:
            flat = kernel.reshape(-1, spec.in_dim, spec.out_dim)
            outs = []
            for flat_i, idx in enumerate(np.ndindex(*spec.stacked)):
                g = a = None
                if needs_gram:
                    suffix = "/".join(str(i) for i in idx)
                    key = (
                        f"{spec.gram_key}/{suffix}"
                        if spec.per_layer_gram
                        else spec.gram_key
                    )
                    min_count = spec.in_dim // 4
                    g = grams.gram(key, fallback=spec.gram_key, min_count=min_count)
                    a = grams.absmean(key, fallback=spec.gram_key, min_count=min_count)
                    if observing:
                        _, reason = grams.resolve(
                            key, fallback=spec.gram_key, min_count=min_count)
                        if reason is not None:
                            fallback_slices += 1
                            telemetry.on_gram_fallback(
                                key, spec.gram_key, reason)
                outs.append(compress_matrix(
                    flat[flat_i], rank, cfg, g, a,
                    telemetry=telemetry, target=spec.name, slice_idx=idx))
            factored = {
                k: jnp.stack([o[k] for o in outs]).reshape(
                    *spec.stacked, *outs[0][k].shape
                )
                for k in outs[0]
            }
        else:
            g = a = None
            if needs_gram:
                g = grams.gram(spec.gram_key)
                a = grams.absmean(spec.gram_key)
            factored = compress_matrix(kernel, rank, cfg, g, a,
                                       telemetry=telemetry, target=spec.name)
        _set_subtree(new_params, spec.path, factored)
        dt = time.time() - t0
        if observing:
            m, n = spec.out_dim, spec.in_dim
            dense_params = m * n * spec.count
            factored_params = spec.count * (m + n) * rank
            telemetry.on_target(
                name=spec.name, method=cfg.method, shape=(m, n),
                stacked=spec.stacked, rank=rank,
                requested_rank=rank_for_ratio(m, n, cfg.ratio),
                requested_ratio=cfg.ratio,
                achieved_ratio=1.0 - factored_params / dense_params,
                dense_params=dense_params, factored_params=factored_params,
                gram_fallback_slices=fallback_slices, seconds=dt)
        logger.info("compressed %s rank=%d in %.2fs", spec.name, rank, dt)
    return new_params


def _to_mutable(tree):
    if isinstance(tree, Mapping):
        return {k: _to_mutable(v) for k, v in tree.items()}
    return tree


def compress_model(
    params: Mapping[str, Any],
    targets,
    grams: GramStore,
    config: CompressionConfig,
    telemetry: Optional[Any] = None,
) -> Tuple[Dict[str, Any], CompressionPlan]:
    """Plan + execute in one call (the public API used by examples)."""
    plan = build_plan(targets, config)
    return compress_params(params, plan, grams, telemetry=telemetry), plan
