"""Low-rank (column) interpolative decomposition — step (5b) alternative.

A column ID approximates A ~= C @ T where C = A[:, J] is k actual columns of
A and T is the interpolation matrix with T[:, J] = I_k.  We implement the
standard pivoted-QR construction (Martinsson, Rokhlin & Tygert 2011):

    A P = Q R,  R = [R11 R12],   C = A[:, J(first k pivots)]
    T = [I_k, R11^{-1} R12] P^T

The draw over SVD is that C keeps *actual weight columns* (sparsity /
quantization-friendliness are preserved) and the factor is cheaper to form.
The paper uses it for the residual step of NID-I/II.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .asvd import LowRankFactors

Array = np.ndarray


def _pivoted_qr(a: Array) -> Tuple[Array, Array, np.ndarray]:
    """Householder QR with column pivoting (numpy-only; no scipy in image).

    Returns (q, r, piv) with a[:, piv] == q @ r and diag(r) non-increasing
    in magnitude.
    """
    a = np.array(a, dtype=np.float64, copy=True)
    m, n = a.shape
    kmax = min(m, n)
    piv = np.arange(n)
    col_norms = np.sum(a * a, axis=0)
    q = np.eye(m)
    for j in range(kmax):
        # Pivot: swap in the column with the largest remaining norm.
        p = j + int(np.argmax(col_norms[j:]))
        if p != j:
            a[:, [j, p]] = a[:, [p, j]]
            piv[[j, p]] = piv[[p, j]]
            col_norms[[j, p]] = col_norms[[p, j]]
        # Householder reflector for column j.
        x = a[j:, j]
        normx = np.linalg.norm(x)
        if normx <= 1e-300:
            col_norms[j:] = 0.0
            continue
        v = x.copy()
        v[0] += np.sign(x[0]) * normx if x[0] != 0 else normx
        v = v / np.linalg.norm(v)
        a[j:, j:] -= 2.0 * np.outer(v, v @ a[j:, j:])
        q[:, j:] -= 2.0 * np.outer(q[:, j:] @ v, v)
        # Downdate remaining column norms.
        if j + 1 < n:
            col_norms[j + 1 :] = np.maximum(col_norms[j + 1 :] - a[j, j + 1 :] ** 2, 0.0)
    r = np.triu(a[:kmax, :])
    return q[:, :kmax], r, piv


def column_id(a: Array, k: int) -> Tuple[np.ndarray, Array]:
    """Rank-k column interpolative decomposition.

    Returns (cols, t): a ~= a[:, cols] @ t, with t (k, n) and
    t[:, cols] == I_k.
    """
    a = np.asarray(a, dtype=np.float64)
    m, n = a.shape
    k = int(min(k, min(m, n)))
    if k == 0:
        return np.zeros(0, dtype=np.int64), np.zeros((0, n))
    _, r, piv = _pivoted_qr(a)
    r11 = r[:k, :k]
    r12 = r[:k, k:]
    # Solve R11 T12 = R12 (upper-triangular).
    if r12.size:
        t12 = np.linalg.solve(r11, r12)
    else:
        t12 = np.zeros((k, 0))
    t_perm = np.concatenate([np.eye(k), t12], axis=1)  # in pivoted order
    t = np.zeros((k, n))
    t[:, piv] = t_perm
    cols = piv[:k].astype(np.int64)
    return cols, t


def id_compress(a: Array, k: int) -> LowRankFactors:
    """A ~= C @ T as LowRankFactors (C = actual columns of A)."""
    a = np.asarray(a, dtype=np.float64)
    cols, t = column_id(a, k)
    c = a[:, cols]
    return LowRankFactors(c, t, method="id")
