"""NSVD / NID — the paper's nested activation-aware decomposition (Eq. 5).

Step (5a): rank-k1 activation-aware truncation (ASVD-I or ASVD-II):
    A~1 = argmin_{rank k1} ||(A - B) X||_F
Step (5b): rank-k2 plain approximation of the *residual*, adhering to A:
    A~2 = argmin_{rank k2} ||B - (A - A~1)||_F        (SVD  -> NSVD)
          or column interpolative decomposition        (ID   -> NID)

Inference: O = W1 (Z1 x) + W2 (Z2 x); with k1 + k2 = k this matches the
FLOPs and storage of a single rank-k ASVD factorization (paper Eq. 6).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from .asvd import LowRankFactors, asvd_compress, gram_loss, plain_svd_compress
from .nid import id_compress
from .whitening import make_whitener

Array = np.ndarray


def split_rank(k: int, k1_frac: float) -> tuple[int, int]:
    """Split budget k into (k1, k2) with k1 = round(k1_frac * k), k2 = k - k1.

    Paper default k1_frac = 0.95; Table 3 sweeps {0.99, 0.95, 0.90, 0.85, 0.80}.
    Guarantees k1 >= 1 when k >= 1 (the activation-aware step always runs) and
    k2 >= 0 (k1_frac == 1.0 degenerates to plain ASVD).
    """
    k = int(k)
    if k <= 0:
        return 0, 0
    k1 = int(round(k1_frac * k))
    k1 = max(1, min(k, k1))
    return k1, k - k1


def nsvd_compress(
    a: Array,
    k: int,
    gram: Array,
    k1_frac: float = 0.95,
    variant: str = "nsvd2",
    damp: float = 1e-6,
    use_randomized: bool = True,
) -> LowRankFactors:
    """Nested compression.

    variant:
      'nsvd1' — step (5a) via Cholesky whitening (Thm 2), (5b) via SVD
      'nsvd2' — step (5a) via eigen-SVD whitening (Thm 3), (5b) via SVD
      'nid1'  — step (5a) via Cholesky whitening, (5b) via column ID
      'nid2'  — step (5a) via eigen-SVD whitening, (5b) via column ID
    """
    v = variant.lower()
    if v not in ("nsvd1", "nsvd2", "nid1", "nid2"):
        raise ValueError(f"unknown nested variant {variant!r}")
    whit_method = "asvd1" if v.endswith("1") else "asvd2"
    residual_id = v.startswith("nid")

    a = np.asarray(a, dtype=np.float64)
    k1, k2 = split_rank(k, k1_frac)
    if k1 == 0:
        raise ValueError("rank budget must be >= 1")

    whit = make_whitener(whit_method, gram=gram, damp=damp)
    first, _ = asvd_compress(a, k1, whit, use_randomized=use_randomized)

    if k2 == 0:
        return LowRankFactors(first.w, first.z, method=v)

    residual = a - first.matrix()
    if residual_id:
        second = id_compress(residual, k2)
    else:
        second = plain_svd_compress(residual, k2, use_randomized=use_randomized)

    return LowRankFactors(
        w=first.w, z=first.z, w2=second.w, z2=second.z, method=v
    )


def nested_compress(
    a: Array,
    k: int,
    method: str,
    gram: Optional[Array] = None,
    absmean: Optional[Array] = None,
    k1_frac: float = 0.95,
    damp: float = 1e-6,
    use_randomized: bool = True,
) -> LowRankFactors:
    """Unified façade over every compressor in the paper.

    method in {svd, asvd0, asvd1, asvd2, asvd3, nsvd1, nsvd2, nid1, nid2}.
    """
    m = method.lower()
    if m in ("nsvd1", "nsvd2", "nid1", "nid2"):
        if gram is None:
            raise ValueError(f"{method} requires a calibration Gram")
        return nsvd_compress(
            a, k, gram, k1_frac=k1_frac, variant=m, damp=damp,
            use_randomized=use_randomized,
        )
    if m in ("svd", "plain"):
        return plain_svd_compress(a, k, use_randomized)
    whit = make_whitener(m, gram=gram, absmean=absmean, damp=damp)
    factors, _ = asvd_compress(a, k, whit, use_randomized=use_randomized)
    return factors


ALL_METHODS = (
    "svd", "asvd0", "asvd1", "asvd2", "asvd3", "nsvd1", "nsvd2", "nid1", "nid2",
)
NESTED_METHODS = ("nsvd1", "nsvd2", "nid1", "nid2")


def decomposition_diagnostics(
    a: Array,
    factors: LowRankFactors,
    gram: Optional[Array] = None,
    compare_plain: bool = True,
    use_randomized: bool = False,
) -> Dict[str, float]:
    """Pure observation of a finished decomposition (never mutates inputs).

    Returns per-matrix quality numbers the compression observability layer
    aggregates into ``DecompositionReport``s:

      plain_rel_err      ||A - Ã||_F / ||A||_F            (weight space)
      whitened_rel_err   ||(A - Ã) X||_F / ||A X||_F      (activation space,
                         computed from the calibration Gram only)
      sv_tail_mass       whitened_rel_err² — for the activation-aware step
                         this is exactly Σ_{i>k} σ_i² / Σ_i σ_i² of A·S
                         (Eckart–Young in the whitened space), so the
                         singular-value tail at the chosen rank costs no
                         extra SVD.
      outlier_absorption 1 - whitened_loss / plain_svd_whitened_loss: the
                         fraction of activation-weighted error the
                         whitening step (absorbing activation outliers
                         into the transformed weight) removed relative to
                         a rank-matched PLAIN SVD.  Requires one extra
                         truncated SVD; skipped when ``compare_plain`` is
                         False (reported as nan).
      k1 / k2            the nested split actually used.
    """
    a = np.asarray(a, np.float64)
    approx = factors.matrix()
    fro_a = float(np.linalg.norm(a, "fro"))
    plain_rel = float(np.linalg.norm(a - approx, "fro")) / max(fro_a, 1e-300)
    k1 = int(factors.w.shape[1])
    k2 = int(factors.w2.shape[1]) if factors.nested else 0
    out: Dict[str, float] = {
        "rank": float(factors.rank),
        "k1": float(k1),
        "k2": float(k2),
        "param_count": float(factors.param_count()),
        "plain_rel_err": plain_rel,
        "whitened_rel_err": float("nan"),
        "sv_tail_mass": float("nan"),
        "outlier_absorption": float("nan"),
    }
    if gram is None:
        return out
    g = np.asarray(gram, np.float64)
    g = 0.5 * (g + g.T)
    total = gram_loss(a, np.zeros_like(a), g)  # ||A X||_F
    whit = gram_loss(a, approx, g)
    out["whitened_rel_err"] = whit / max(total, 1e-300)
    out["sv_tail_mass"] = (whit / max(total, 1e-300)) ** 2
    if compare_plain:
        base = plain_svd_compress(a, factors.rank, use_randomized=use_randomized)
        base_whit = gram_loss(a, base.matrix(), g)
        out["outlier_absorption"] = 1.0 - whit / max(base_whit, 1e-300)
    return out
