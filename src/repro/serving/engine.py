"""Batched serving engine (continuous-batching-lite) over (compressed)
weights.

Slot-based: a fixed (max_batch, max_len) cache; requests are admitted into
free slots (per-row prefill written into the slot via dynamic updates),
every engine step decodes one token for all live rows, finished rows free
their slots immediately — new requests join mid-flight without stalling
the running batch.  Greedy or temperature sampling.

This is the decode path the nested_lowrank Pallas kernel serves on TPU;
on CPU the jnp twin runs (ops.py dispatch).
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import _CACHE_LEAF_RULES
from repro.models.api import Model


def _walk_cache(tree, fn, name=""):
    """Apply fn(leaf, batch_axis) over a cache pytree (stacked scan groups
    put layer dims BEFORE the batch dim; the leaf name determines its base
    rank, hence where batch sits)."""
    if isinstance(tree, dict):
        return {k: _walk_cache(v, fn, k) for k, v in tree.items()}
    base_ndim = _CACHE_LEAF_RULES[name][0]
    return fn(tree, tree.ndim - base_ndim)


def slice_cache_row(cache, slot: int):
    return _walk_cache(
        cache, lambda c, ax: jax.lax.slice_in_dim(c, slot, slot + 1, axis=ax)
    )


def set_cache_row(cache, row, slot: int):
    def walk(c, r, name=""):
        if isinstance(c, dict):
            return {k: walk(c[k], r[k], k) for k in c}
        ax = c.ndim - _CACHE_LEAF_RULES[name][0]
        idx = [slice(None)] * c.ndim
        idx[ax] = slice(slot, slot + 1)
        return c.at[tuple(idx)].set(r)

    return walk(cache, row)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        max_batch: int = 8,
        max_len: int = 512,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = model.init_cache(max_batch, max_len)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.active = np.zeros((max_batch,), bool)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._uid = itertools.count()
        self._rng = jax.random.key(seed)

        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("plen",))

    # --------------------------------------------------------------- API

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               temperature: float = 0.0) -> int:
        req = Request(next(self._uid), np.asarray(prompt, np.int32),
                      max_new_tokens, temperature)
        self.queue.append(req)
        return req.uid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue + slots drain.  Returns uid -> generated."""
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._admit()
            if not self.active.any():
                if not self.queue:
                    break
                continue
            for req in self.step():
                finished[req.uid] = req.generated
        return finished

    # ------------------------------------------------------------- internals

    def _admit(self):
        while self.queue and not self.active.all():
            slot = int(np.argmin(self.active))
            req = self.queue.popleft()
            req.slot = slot
            self.slots[slot] = req
            self.active[slot] = True
            self._prefill_into_slot(req, slot)

    def _prefill_fn(self, params, cache, tokens, plen: int):
        """Single-request prefill; returns (last_logits, row cache)."""
        logits, new_cache, _ = self.model.apply(
            params, tokens, mode="prefill", cache=cache
        )
        return logits[:, -1], new_cache

    def _prefill_into_slot(self, req: Request, slot: int):
        plen = len(req.prompt)
        row_cache = slice_cache_row(self.cache, slot)
        # Zero the row state (previous occupant) before prefill.
        row_cache = jax.tree.map(jnp.zeros_like, row_cache)
        tokens = jnp.asarray(req.prompt[None, :])
        logits, row_cache = self._prefill(self.params, row_cache, tokens, plen)
        self.cache = set_cache_row(self.cache, row_cache, slot)
        self.cache_len = self.cache_len.at[slot].set(plen)
        tok = self._sample(logits[0], req.temperature)
        self.last_token = self.last_token.at[slot].set(tok)
        req.generated.append(int(tok))

    def _decode_fn(self, params, cache, last_token, cache_len):
        logits, new_cache, _ = self.model.apply(
            params, last_token[:, None], mode="decode",
            cache=cache, cache_len=cache_len,
        )
        return logits[:, 0], new_cache

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(sub, logits / temperature).astype(jnp.int32)

    def step(self) -> List[Request]:
        """One decode step for all live rows; returns requests finished."""
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_token, self.cache_len
        )
        self.cache_len = self.cache_len + jnp.asarray(self.active, jnp.int32)
        finished = []
        new_last = np.array(self.last_token)
        for slot, req in enumerate(self.slots):
            if req is None or not self.active[slot]:
                continue
            tok = self._sample(logits[slot], req.temperature)
            req.generated.append(int(tok))
            new_last[slot] = int(tok)
            if req.done or self.cache_len[slot] >= self.max_len - 1:
                finished.append(req)
                self.slots[slot] = None
                self.active[slot] = False
        self.last_token = jnp.asarray(new_last)
        return finished
