"""Batched, host-sync-free serving engine (continuous batching) over
(compressed) weights, with a paged (block-table) KV cache for attention
models and a dense (max_batch, max_len) slab fallback for everything else.

Slot-based: requests are admitted into free slots, every engine step decodes
one token for all live rows, finished rows free their slot — and their KV
blocks — immediately, so new requests join mid-flight without stalling the
running batch.

Hot-path design (the paper's Eq. 6 payoff is only real if the engine's
memory path keeps up with the factored matmuls):

  * ALL per-slot state lives on device: cache, cache_len, last_token,
    active flags and a per-slot PRNG key array.  The host mirrors only what
    it needs for scheduling, updated from host-side bookkeeping plus the one
    token vector each step already transfers — never by extra syncs.
  * Every jit root DONATES its cache/state buffers (``donate_argnums``), so
    the multi-MB cache is aliased in place by XLA instead of being copied
    every step.
  * ``step()`` is ONE jitted call (decode + batched sampling + device-side
    finish exits for every live row) followed by AT MOST one device->host
    transfer of a sampled token vector.  A row that samples its eos id,
    spends its last budgeted token, or hits the max_len bound clears its
    own active flag on device; the host learns from the tokens it already
    has.
  * The step loop is PIPELINED (``pipeline_depth``, default 2): because
    every finish reason is device-authoritative, step N+1's decode root can
    be dispatched before step N's token transfer is consumed — the engine
    keeps a small ring of in-flight token futures and syncs only the oldest
    when the ring is full, so token emission, slot/block freeing and
    request admission bookkeeping overlap the device's next step instead
    of serializing behind a host round-trip every token.  Depth 1 is
    bit-for-bit the unpipelined engine, and any depth produces identical
    token streams (the device state chain never observes the host's lag).
    Host-mutating events that need a synced view — admission, defrag,
    dynamic-k speculation — drain the ring first (``drain()``).

Paged path (``models.api.cache_layout(model) == "paged"``: pure-attention
stacks — see serving/kvcache/):

  * K/V live in a shared block pool (num_blocks, block_size, ...) instead of
    a dense slab, addressed through per-slot block-table rows, so cache HBM
    scales with pool capacity (live tokens), not max_batch * max_len.
  * Admission reserves each request's worst-case blocks up front
    (per-request max_len = prompt + max_new_tokens): exhaustion surfaces
    only as admission backpressure, never mid-decode.
  * Prefill is CHUNKED: prompts stream into their blocks ``prefill_chunk``
    tokens per engine iteration through one fixed-shape jit root (compiles
    exactly once), interleaved with decode steps so a very long prompt
    cannot stall the running batch.
  * Decode attends through ``kernels/paged_attention`` (Pallas kernel
    streaming exactly the live pages on TPU, jnp gather oracle elsewhere),
    honoring the int8 KV quantization of the dense path.

Dense path (recurrent SSM/RWKV state, token-choice MoE, MLA latents,
enc-dec): the PR-1 design — bucketed batched prefill-admission (pad-safe
models compile once per power-of-two prompt-length bucket; pad-sensitive
ones fall back to exact-length prefill) — now with donated jit roots and the
same device-side EOS exit.

Decode-time nested-lowrank matmuls of compressed layers (dense, attention,
MLP, and the stacked MoE expert FFNs) route through
``kernels/nested_lowrank/ops.py`` (fused Pallas kernel on TPU for
decode-shaped rows, jnp oracle on CPU).

Speculative decoding (``spec_config``, serving/spec/): a higher-compression
NSVD twin of the weights drafts ``k`` tokens per step in one fused jit root
(K+1 sequential cheap decodes over the draft's own paged/dense cache); the
target verifies the whole proposal matrix through the same S>1 chunk-decode
path chunked prefill uses and commits the accepted prefix plus one
correction/bonus token via on-device accept/resample — greedy is
token-identical to non-speculative decode, temperature>0 preserves the
target distribution exactly.  Both caches' per-row lengths roll back to the
committed prefix on device; a step is still exactly two jitted calls and
ONE D2H transfer (the packed committed-token matrix).

Mesh sharding (``parallelism=`` over a launch/mesh.make_serving_mesh DP x TP
mesh): every jit root runs SPMD with explicit in/out NamedShardings
(launch/steps.ServingShardings) — weights TP-sharded via the existing
param_pspecs (factored NSVD layers all-reduce rank-k partials, not
d_model), per-slot state and host-built (B, ...) inputs data-parallel over
slots, the dense slab sharded over its batch dim and the paged block pools
over their block dim with PER-SHARD block id ranges: slot s maps to DP
shard s*dp/max_batch, its reservations come from that shard's range, and
admission/free/defrag/rollback stay host-authoritative per shard
(serving/kvcache).  The donation and one-D2H-per-step contracts are
unchanged — sampled tokens leave via ONE sharded transfer, and a (1, 1)
mesh reproduces the meshless single-device engine bit-for-bit (pinned by
tests/test_sharded_serving.py).  When max_batch does not divide the DP
size, slot/pool sharding falls back to replicated (weights stay TP).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import (
    RootContext,
    ServingShardings,
    named,
    serving_root_registry,
)
from repro.models.api import (
    Model,
    build_model,
    cache_layout,
    prefill_pad_safe,
    serving_cache_pspecs,
)
from repro.obs import NULL_TELEMETRY
from repro.parallel.sharding import Parallelism
from repro.serving.kvcache import PagedKVCache
from repro.serving.spec import DraftState, SpecConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    # Speculative-decoding accounting (spec_config engines only).
    spec_proposed: int = 0
    spec_accepted: int = 0
    # Lifecycle timestamps (time.perf_counter; populated only when the
    # engine runs with telemetry enabled — see repro.obs).
    t_submit: float = 0.0
    t_first: float = 0.0
    t_last: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def acceptance_rate(self) -> float:
        return self.spec_accepted / max(1, self.spec_proposed)


@dataclasses.dataclass
class _PrefillTask:
    """A request streaming its prompt into reserved blocks, chunk by chunk."""
    req: Request
    slot: int
    pos: int = 0  # next prompt position to feed


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unconsumed decode step in the pipeline ring.

    ``tokens`` is the step's device-resident result (the sampled token
    vector, or the packed [tokens|n_commit|m] matrix in speculative mode);
    ``mask`` snapshots the host's active view at dispatch so consumption
    attributes tokens to the rows that were live then.  FIFO consumption
    keeps the invariant that a row live on the host at consume time was
    device-active at this entry's dispatch (every device exit has a host
    twin that fires when the triggering entry is consumed — earlier in the
    ring by construction)."""
    tokens: jax.Array
    mask: np.ndarray
    dispatch_s: float
    spec: bool = False
    k_row: Optional[np.ndarray] = None


_PIPELINE_DEPTH_ENV = "REPRO_SERVING_PIPELINE_DEPTH"
_TRANSFER_GUARD_ENV = "REPRO_SERVING_TRANSFER_GUARD"


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        max_batch: int = 8,
        max_len: int = 512,
        seed: int = 0,
        bucket_min: int = 16,
        paged: Optional[bool] = None,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        prefill_chunk: int = 64,
        eos_id: Optional[int] = None,
        kv_quant: bool = False,
        spec_config: Optional[SpecConfig] = None,
        parallelism: Optional[Parallelism] = None,
        pipeline_depth: Optional[int] = None,
        transfer_guard: Optional[bool] = None,
        telemetry=None,
    ):
        # Observability (repro.obs.Telemetry, or the shared no-op).  All
        # hooks consume host bookkeeping + the packed D2H word the step
        # already transfers — never an extra device sync — and per-row
        # work is gated on ``self.obs.enabled`` so the default path stays
        # no-op (pinned by tests/test_observability.py).
        self.obs = telemetry if telemetry is not None else NULL_TELEMETRY
        self._obs_blocked: set = set()
        if self.obs.enabled and spec_config is not None:
            self.obs.spec_meta.setdefault("k", spec_config.k)
            if spec_config.draft_ratio is not None:
                self.obs.spec_meta.setdefault("draft_ratio",
                                              spec_config.draft_ratio)
        if pipeline_depth is None:
            pipeline_depth = int(os.environ.get(_PIPELINE_DEPTH_ENV, "2"))
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}"
            )
        self.pipeline_depth = pipeline_depth
        if transfer_guard is None:
            transfer_guard = os.environ.get(
                _TRANSFER_GUARD_ENV, "0").lower() not in ("", "0", "false")
        self.transfer_guard = bool(transfer_guard)
        par = (parallelism
               if parallelism is not None and parallelism.active else None)
        self.par = par
        if par is not None:
            # Rebuild the model facade against the mesh so its internal
            # activation constraints (batch DP, logits TP) apply inside
            # every root; params/caches are plain pytrees, so the rebuilt
            # facade is interchangeable with the caller's.
            model = build_model(model.cfg, par)
            dp_size = int(np.prod([par.mesh.shape[a] for a in par.dp_axes]))
            # Slots (and with them the paged pools' block ranges) shard
            # over DP only when they divide it; otherwise per-slot state
            # and the cache stay replicated while weights keep TP.
            self.dp_shards = dp_size if max_batch % dp_size == 0 else 1
        else:
            self.dp_shards = 1
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id

        layout = cache_layout(model)
        self.paged = (layout == "paged") if paged is None else bool(paged)
        if self.paged and layout != "paged":
            raise ValueError(
                f"model {model.cfg.name!r} has cache layout {layout!r}; "
                "paging requires a pure-attention cache (models.api.cache_layout)"
            )
        self.spec = spec_config
        if self.spec is not None and layout != "paged":
            raise ValueError(
                f"model {model.cfg.name!r} has cache layout {layout!r}; "
                "speculative decoding needs pure-attention caches (chunk "
                "verification and length rollback have no recurrent/MoE/MLA "
                "form)"
            )

        # Device-resident state (never read back except the sampled tokens).
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.budget_dev = jnp.zeros((max_batch,), jnp.int32)
        self.key_data = jax.random.key_data(
            jax.random.split(jax.random.key(seed), max_batch)
        )
        # Per-request key derivation (see Request.key_data).
        self._base_key = jax.random.key(seed)
        self._draft_base_key = (jax.random.key(spec_config.seed)
                                if spec_config is not None else None)
        self._active_dev = jnp.zeros((max_batch,), bool)

        # Host mirrors for scheduling (updated by bookkeeping + the step's
        # own token transfer, not extra syncs).
        self.active = np.zeros((max_batch,), bool)
        self.temps = np.zeros((max_batch,), np.float32)
        self._eos = np.full((max_batch,), -1, np.int32)
        self._len_host = np.zeros((max_batch,), np.int64)

        # Device-resident copies of the loop-invariant host inputs
        # (host_keep / temps / eos [/ k_row]).  They only change on slot
        # (re)admission or a finish, so dispatch reuses the cached arrays
        # instead of re-uploading three (B,) host arrays every step; any
        # bookkeeping that mutates them flips ``_host_dirty``.
        self._host_dirty = True
        self._keep_dev = None
        self._temps_dev = None
        self._eos_dev = None
        self._k_row_dev = None

        # Pipeline ring of dispatched-but-unconsumed steps, plus finished
        # requests produced by internal drains (handed out by the next
        # public step()/_admit()/drain()).
        self._ring: deque[_InFlight] = deque()
        self._pending_finished: List[Request] = []

        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._prefilling: List[_PrefillTask] = []
        self._uid = itertools.count()
        # Free slots are handed out in the order they FREED, not by index.
        # Token streams never depend on slot choice (sampling keys are
        # per-REQUEST, see Request.key_data), but freed-order assignment
        # keeps slot/pool layouts closer across pipeline depths — at depth
        # 2 two finishes can surface from one drain, and an index-ordered
        # free list would swap their successors' slots relative to depth 1.
        self._free_clock = itertools.count()
        self._freed_at = np.arange(max_batch, dtype=np.int64) - max_batch
        self._bucketed = prefill_pad_safe(model)

        if self.paged:
            self.kv = PagedKVCache(
                model, max_batch, max_len, block_size=block_size,
                num_blocks=num_blocks, kv_quant=kv_quant,
                dp_shards=self.dp_shards, par=par,
            )
            self.prefill_chunk = prefill_chunk
            self._sh = (ServingShardings(par, params, self.kv.shardings,
                                         max_batch)
                        if par is not None else None)
            if par is not None:
                self.params = params = jax.device_put(params,
                                                      self._sh.params)
                # Cached block-table mirror must be born with the roots'
                # expected (B, M) sharding (see PagedKVCache.table_device).
                self.kv.table_sharding = self._sh.mat
        else:
            self.cache = model.init_cache(max_batch, max_len,
                                          kv_quant=kv_quant)
            self._sh = None
            if par is not None:
                cache_sh = named(
                    serving_cache_pspecs(model, par, max_batch=max_batch,
                                         max_len=max_len,
                                         kv_quant=kv_quant,
                                         shapes=self.cache),
                    par.mesh,
                )
                self._sh = ServingShardings(par, params, cache_sh,
                                            max_batch)
                self.params = params = jax.device_put(params,
                                                      self._sh.params)
                self.cache = jax.device_put(self.cache, cache_sh)
            self._buckets = self._make_buckets(bucket_min, max_len)

        # All jit roots come from the serving root registry (the same specs
        # the static auditor traces): builder, donate_argnums and sharding
        # hook live in ONE place, so an audited contract is by construction
        # the contract the engine runs.
        self._ctx = RootContext(
            model=model, max_batch=max_batch, max_len=max_len,
            kv_quant=kv_quant, prefill_chunk=prefill_chunk,
            block_size=block_size,
            num_blocks=self.kv.num_blocks if self.paged else None,
            spec_k=spec_config.k if spec_config is not None else 4,
            bucketed=self._bucketed, dp_shards=self.dp_shards,
        )
        self._roots = {r.name: r for r in serving_root_registry(
            "paged" if self.paged else "dense",
            spec=spec_config is not None)}
        if self.paged:
            self._decode = self._root("paged_decode")
            self._chunk_step = self._root("paged_prefill_chunk")
        else:
            self._decode = self._root("decode")
            self._prefill = self._root("prefill_admit")

        if self._sh is not None:
            # Per-slot device state lives sharded from birth so the roots'
            # donated buffers alias in place (resharding would copy).
            self.cache_len = jax.device_put(self.cache_len, self._sh.row)
            self.last_token = jax.device_put(self.last_token, self._sh.row)
            self.budget_dev = jax.device_put(self.budget_dev, self._sh.row)
            self.key_data = jax.device_put(self.key_data, self._sh.mat)
            self._active_dev = jax.device_put(self._active_dev,
                                              self._sh.row)

        if self.spec is not None:
            draft_params = self.spec.draft_params
            dparams_sh = None
            if self._sh is not None:
                # Draft weights follow the same TP rules (factored leaves
                # shard by the u/v orientation rules); the draft cache
                # inherits the target's shardings by construction.
                dparams_sh = self._sh.tree(draft_params)
                draft_params = jax.device_put(draft_params, dparams_sh)
            self.draft = DraftState(
                model, draft_params, max_batch, max_len,
                paged=self.paged, block_size=block_size,
                num_blocks=num_blocks, kv_quant=kv_quant,
                seed=self.spec.seed, dp_shards=self.dp_shards, par=par,
                cache_shardings=(None if self.paged or self._sh is None
                                 else self._sh.cache),
                key_sharding=self._sh.mat if self._sh else None,
            )
            if self.paged and self._sh is not None:
                self.draft.kv.table_sharding = self._sh.mat
            self._spec_draft = self._root("spec_draft", dparams_sh)
            self._spec_verify = self._root("spec_verify", dparams_sh)
            self._draft_prefill = self._root("draft_prefill", dparams_sh)
            # Per-row speculation windows (all k unless dynamic_k shrinks).
            self._k_row = np.full((max_batch,), self.spec.k, np.int32)
            self.spec_proposed = 0
            self.spec_accepted = 0
            self.spec_committed = 0
            self.spec_step_rows = 0
        else:
            self.draft = None

        # Telemetry: per-consumed-step wall times (dispatch + D2H sync +
        # host bookkeeping) plus the sync/host breakdown the benchmark
        # reports (device wait vs host-side work per step).
        self.step_times: List[float] = []
        self.step_device_wait_s: List[float] = []
        self.step_host_s: List[float] = []
        self.decode_transfers = 0

    @staticmethod
    def _jit(fn, donate, shardings=None):
        """jit a serving root: donation always; explicit in/out shardings
        when the engine runs on a mesh (pinning donated-buffer aliasing and
        step-to-step layout stability)."""
        if shardings is None:
            return jax.jit(fn, donate_argnums=donate)
        in_sh, out_sh = shardings
        return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate)

    def _root(self, name: str, draft_params_sh=None):
        """Build one jitted serving root from its registry spec."""
        spec = self._roots[name]
        sh = (spec.shardings(self._sh, self._ctx, draft_params_sh)
              if self._sh is not None else None)
        return self._jit(spec.build(self._ctx), spec.donate, sh)

    def _guard(self):
        """Steady-state transfer guard (opt-in, ``transfer_guard=`` or
        REPRO_SERVING_TRANSFER_GUARD=1): the decode/spec dispatch path runs
        under jax.transfer_guard("disallow"), so any IMPLICIT device<->host
        transfer — a stray numpy input, a silent sync — raises instead of
        silently serializing the pipeline.  The engine's own sanctioned
        movements (cached host-input rebuilds, block-table mirror uploads)
        are explicit jax.device_put calls, and the per-step token readback
        is an explicit jax.device_get outside the guarded region."""
        if self.transfer_guard:
            return jax.transfer_guard("disallow")
        return contextlib.nullcontext()

    # --------------------------------------------------------------- API

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               temperature: float = 0.0,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be positive, got {max_new_tokens}"
            )
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds max_len-1={self.max_len - 1}"
            )
        if self.paged:
            # Admission reserves the worst case up front; a request whose
            # worst case exceeds one DP shard's sub-pool (== the total pool
            # when unsharded) could never be admitted and would stall the
            # FIFO head forever — fail fast at submit.
            need = min(self.max_len, len(prompt) + max_new_tokens)
            n_blocks = self.kv.blocks_for(need)
            if n_blocks > self.kv.blocks_per_shard:
                raise ValueError(
                    f"request needs {n_blocks} blocks worst-case "
                    f"(prompt {len(prompt)} + max_new {max_new_tokens}) but "
                    f"a pool shard only has {self.kv.blocks_per_shard} "
                    f"(num_blocks={self.kv.num_blocks} over "
                    f"{self.kv.dp_shards} DP shard(s))"
                )
        req = Request(next(self._uid), prompt, max_new_tokens, temperature,
                      eos_id if eos_id is not None else self.eos_id)
        if self.obs.enabled:
            req.t_submit = time.perf_counter()
            self.obs.on_submit(req.uid, len(prompt), max_new_tokens)
        self.queue.append(req)
        return req.uid

    def _request_keys(self, uids, draft: bool = False) -> np.ndarray:
        """(N, 2) uint32 per-request PRNG key data — fold_in(seed, uid),
        one vmapped dispatch per admission group (keys depend only on the
        engine/draft seed and the uid, never on scheduling)."""
        base = self._draft_base_key if draft else self._base_key
        return np.asarray(jax.vmap(
            lambda u: jax.random.key_data(jax.random.fold_in(base, u))
        )(jnp.asarray(uids, jnp.uint32)))

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue + prefills + slots drain.  uid -> generated.

        Admission runs only when it could actually progress (see
        ``_admission_could_progress``) — the host checks are free, and
        calling ``_admit`` while the batch is full or the pool is
        backpressured (the saturated regimes) would drain the step
        pipeline every iteration and forfeit exactly the overlap it
        exists for; a slot/block freed by an in-flight step surfaces when
        step() consumes it, one iteration later."""
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if self._admission_could_progress():
                for req in self._admit():
                    finished[req.uid] = req.generated
            if not self.active.any():
                # The host may only THINK rows are done pending in-flight
                # transfers: flush the ring, then re-check.
                for req in self.drain():
                    finished[req.uid] = req.generated
                if not self.active.any():
                    if not self.queue and not self._prefilling:
                        break
                    continue
            for req in self.step():
                finished[req.uid] = req.generated
        return finished

    # ------------------------------------------------------------- admission

    def _admit(self) -> List[Request]:
        """Admit queued requests (returns any that finish at admission,
        plus any finished by the pipeline drain admission requires).

        Drain discipline: admission reads the host's free-slot / block
        views and scatters fresh per-slot state, so every in-flight step
        must be consumed first — the ring is empty while the prefill roots
        run, and no in-flight entry ever straddles a slot's change of
        occupant."""
        self._drain_ring()
        finished = self._pop_finished()
        finished.extend(
            self._admit_paged() if self.paged else self._admit_dense()
        )
        return finished

    def _obs_finish(self, req: Request) -> None:
        """Report one finished request (TTFT/TPOT from its timestamps)."""
        n = len(req.generated)
        ttft = req.t_first - req.t_submit if req.t_submit else 0.0
        tpot = ((req.t_last - req.t_first) / (n - 1)
                if n > 1 and req.t_last > req.t_first else 0.0)
        self.obs.on_finish(req.uid, n, ttft, tpot)

    def _finish_or_activate(self, req: Request, slot: int, tok: int,
                            finished: List[Request]) -> None:
        """Shared post-prefill bookkeeping for a request's first token."""
        req.slot = slot
        req.generated.append(tok)
        if self.obs.enabled:
            req.t_first = req.t_last = time.perf_counter()
            self.obs.on_first_token(req.uid, slot,
                                    req.t_first - req.t_submit
                                    if req.t_submit else 0.0)
        self.temps[slot] = req.temperature
        self._eos[slot] = -1 if req.eos_id is None else req.eos_id
        self._len_host[slot] = len(req.prompt)
        self._host_dirty = True
        if self.spec is not None:
            self._k_row[slot] = self.spec.k  # fresh speculation window
        if (req.done or self._len_host[slot] >= self.max_len - 1
                or tok == self._eos[slot]):
            finished.append(req)
            self._retire_slot(slot)
            if self.obs.enabled:
                self._obs_finish(req)
        else:
            self.slots[slot] = req
            self.active[slot] = True

    # ---- paged: reserve blocks, stream prompts chunkwise

    def _free_slots(self, busy=frozenset()) -> List[int]:
        """Free slots in the order they freed (see ``_freed_at``)."""
        return sorted(
            (i for i in range(self.max_batch)
             if not self.active[i] and i not in busy),
            key=lambda i: self._freed_at[i],
        )

    def _retire_slot(self, slot: int) -> None:
        """Shared retirement bookkeeping for EVERY finish path (admission
        finishes and both commit paths): release the slot, invalidate the
        cached host inputs, stamp the freed-order clock, free KV blocks."""
        self.slots[slot] = None
        self.active[slot] = False
        self._host_dirty = True
        self._freed_at[slot] = next(self._free_clock)
        if self.paged:
            self.kv.free(slot)  # blocks reusable immediately
        if self.spec is not None:
            self.draft.free(slot)

    def _admission_could_progress(self) -> bool:
        """Cheap host-side check gating _admit() calls from run(): a
        prefill is mid-flight, or the FIFO head could plausibly land in a
        free slot (paged: and its worst case fits today's free blocks,
        target AND draft pools) — otherwise calling _admit would drain the
        step pipeline every iteration just to back off again."""
        if self._prefilling:
            return True
        if not self.queue or self.active.all():
            return False
        if self.paged:
            head = self.queue[0]
            need = min(self.max_len, len(head.prompt) + head.max_new_tokens)
            n_blocks = self.kv.blocks_for(need)
            if self.kv.alloc.free_blocks() < n_blocks:
                return False
            if (self.spec is not None
                    and self.draft.kv.alloc.free_blocks() < n_blocks):
                return False
        return True

    def _admit_paged(self) -> List[Request]:
        finished: List[Request] = []
        busy = {t.slot for t in self._prefilling}
        while self.queue:
            free = self._free_slots(busy)
            if not free:
                break
            req = self.queue[0]
            need = min(self.max_len, len(req.prompt) + req.max_new_tokens)
            # Block reservations are per DP shard (slot s -> shard
            # s*dp/max_batch), so the FIFO head tries every free slot —
            # different slots may land on shards with different headroom.
            # Unsharded pools reduce to the old single-attempt semantics
            # (every slot shares one shard, so one failure implies all).
            slot = None
            for cand in free:
                if not self.kv.reserve(cand, need):
                    if self.kv.alloc.in_use(self.kv.slot_shard(cand)) == 0:
                        raise RuntimeError(
                            f"request {req.uid} needs "
                            f"{self.kv.blocks_for(need)} blocks but an idle "
                            f"pool shard only has "
                            f"{self.kv.blocks_per_shard}"
                        )
                    continue
                if (self.spec is not None
                        and not self.draft.reserve(cand, need)):
                    # Draft pool is reserved in lockstep with the target's:
                    # on failure roll the target reservation back and try
                    # the next shard (or wait).
                    self.kv.free(cand)
                    continue
                slot = cand
                break
            if slot is None:
                # Every shard exhausted: FIFO backpressure.  Flag the live
                # row holding the most blocks as preempt-ready ONCE per
                # blocked request — the signal a future continuous-batching
                # scheduler consumes (nothing preempts today).
                if self.obs.enabled and req.uid not in self._obs_blocked:
                    self._obs_blocked.add(req.uid)
                    owners = {t.slot: t.req for t in self._prefilling}
                    owners.update({s: r for s, r in enumerate(self.slots)
                                   if r is not None})
                    cand = max(owners,
                               key=lambda s: len(self.kv.alloc.owned_by(s)),
                               default=None)
                    if cand is not None:
                        self.obs.on_preempt_ready(owners[cand].uid, cand)
                break
            self.queue.popleft()
            busy.add(slot)
            if self.obs.enabled:
                self.obs.on_admit(req.uid, slot,
                                  time.perf_counter() - req.t_submit)
            self._prefilling.append(_PrefillTask(req, slot))
        if self._prefilling:
            finished.extend(self._prefill_tick())
        return finished

    def _prefill_tick(self) -> List[Request]:
        """Advance every in-flight prefill by ONE chunk (single jit call).
        run() interleaves these ticks with decode steps, so long prompts
        stream in without stalling live rows."""
        c = self.prefill_chunk
        r_rows = self.max_batch
        tasks = self._prefilling[:r_rows]
        tokens = np.zeros((r_rows, c), np.int32)
        starts = np.zeros((r_rows,), np.int32)
        nvalid = np.ones((r_rows,), np.int32)
        fslots = np.full((r_rows,), self.max_batch, np.int32)  # pad = dropped
        budgets = np.zeros((r_rows,), np.int32)
        rkeys = np.zeros((r_rows, 2), np.uint32)
        d_keys = (np.zeros((r_rows, 2), np.uint32)
                  if self.spec is not None else None)
        temps = np.zeros((r_rows,), np.float32)
        bt_rows = np.full((r_rows, self.kv.max_blocks_per_row), -1, np.int32)
        d_bt = (np.full((r_rows, self.kv.max_blocks_per_row), -1, np.int32)
                if self.spec is not None else None)
        fin: List[tuple] = []
        for r, task in enumerate(tasks):
            p = task.req.prompt
            n = min(len(p) - task.pos, c)
            if self.obs.enabled and task.pos == 0:
                self.obs.on_first_chunk(task.req.uid, task.slot)
            tokens[r, :n] = p[task.pos: task.pos + n]
            starts[r] = task.pos
            nvalid[r] = n
            temps[r] = task.req.temperature
            bt_rows[r] = self.kv.table_np[task.slot]
            if d_bt is not None:
                d_bt[r] = self.draft.kv.table_np[task.slot]
            task.pos += n
            if task.pos >= len(p):
                fslots[r] = task.slot
                budgets[r] = max(0, task.req.max_new_tokens - 1)
                fin.append((r, task))
        if fin:
            # Per-request sampling chains for the finishing rows (one
            # batched fold_in dispatch; see Request/_request_keys).
            uids = [t.req.uid for _, t in fin]
            fr = [r for r, _ in fin]
            rkeys[fr] = self._request_keys(uids)
            if d_keys is not None:
                d_keys[fr] = self._request_keys(uids, draft=True)
        tok_dev, starts_dev = jnp.asarray(tokens), jnp.asarray(starts)
        fslots_dev = jnp.asarray(fslots)
        (first, self.kv.pools, self.cache_len, self.last_token,
         self.budget_dev, self.key_data, self._active_dev) = self._chunk_step(
            self.params, self.kv.pools, jnp.asarray(bt_rows),
            tok_dev, starts_dev, jnp.asarray(nvalid),
            fslots_dev, jnp.asarray(budgets), jnp.asarray(rkeys),
            self.cache_len, self.last_token, self.budget_dev, self.key_data,
            jnp.asarray(temps), self._active_dev,
        )
        if self.spec is not None:
            # Stream the same chunk into the draft pools (its own block
            # tables; lengths/last tokens are shared with the target) and
            # reset finishing rows' draft keys to their requests' chains.
            self.draft.pools, self.draft.key_data = self._draft_prefill(
                self.draft.params, self.draft.pools, jnp.asarray(d_bt),
                tok_dev, starts_dev, fslots_dev, self.draft.key_data,
                jnp.asarray(d_keys),
            )
        finished: List[Request] = []
        if fin:
            toks = np.asarray(jax.device_get(first))
            done_tasks = {id(t) for _, t in fin}
            for r, task in fin:
                self._finish_or_activate(task.req, task.slot, int(toks[r]),
                                         finished)
            self._prefilling = [t for t in self._prefilling
                                if id(t) not in done_tasks]
        return finished

    # ---- dense: bucketed batched prefill-admission (PR 1 path)

    @staticmethod
    def _make_buckets(bucket_min: int, max_len: int) -> List[int]:
        buckets = []
        b = bucket_min
        while b < max_len:
            buckets.append(b)
            b *= 2
        buckets.append(max_len)
        return buckets

    def _bucket(self, plen: int) -> int:
        for b in self._buckets:
            if plen <= b:
                return b
        return self.max_len

    def _take_group(self, max_r: int) -> List[Request]:
        """Pop up to max_r queued requests sharing the front request's
        prompt-length bucket (FIFO within the bucket)."""
        if not self.queue:
            return []
        if not self._bucketed:
            # Recurrent state: exact-length prefill, one request at a time.
            return [self.queue.popleft()]
        want = self._bucket(len(self.queue[0].prompt))
        group, rest = [], deque()
        while self.queue:
            req = self.queue.popleft()
            if len(group) < max_r and self._bucket(len(req.prompt)) == want:
                group.append(req)
            else:
                rest.append(req)
        self.queue = rest
        return group

    def _admit_dense(self) -> List[Request]:
        finished: List[Request] = []
        while self.queue:
            free = self._free_slots()
            if not free:
                break
            group = self._take_group(len(free))
            if not group:
                break
            if self._bucketed:
                plen_pad = self._bucket(max(len(r.prompt) for r in group))
                rows = self.max_batch  # fixed shape: compiles per bucket only
            else:
                plen_pad = len(group[0].prompt)
                rows = 1
            tokens = np.zeros((rows, plen_pad), np.int32)
            plens = np.ones((rows,), np.int32)
            slots = np.full((rows,), self.max_batch, np.int32)  # pad = dropped
            budgets = np.zeros((rows,), np.int32)
            rkeys = np.zeros((rows, 2), np.uint32)
            d_keys = (np.zeros((rows, 2), np.uint32)
                      if self.spec is not None else None)
            temps = np.zeros((rows,), np.float32)
            for r, req in enumerate(group):
                tokens[r, : len(req.prompt)] = req.prompt
                plens[r] = len(req.prompt)
                slots[r] = free[r]
                budgets[r] = max(0, req.max_new_tokens - 1)
                temps[r] = req.temperature
                if self.obs.enabled:
                    self.obs.on_admit(req.uid, free[r],
                                      time.perf_counter() - req.t_submit)
            uids = [req.uid for req in group]
            rkeys[: len(group)] = self._request_keys(uids)
            if d_keys is not None:
                d_keys[: len(group)] = self._request_keys(uids, draft=True)
            slots_dev = jnp.asarray(slots)
            (first, self.cache, self.cache_len, self.last_token,
             self.budget_dev, self.key_data, self._active_dev) = self._prefill(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(plens), slots_dev,
                jnp.asarray(budgets), jnp.asarray(rkeys), self.cache_len,
                self.last_token, self.budget_dev, self.key_data,
                jnp.asarray(temps), self._active_dev,
            )
            if self.spec is not None:
                self.draft.cache, self.draft.key_data = self._draft_prefill(
                    self.draft.params, self.draft.cache,
                    jnp.asarray(tokens), slots_dev, self.draft.key_data,
                    jnp.asarray(d_keys),
                )
            toks = np.asarray(jax.device_get(first))
            for r, req in enumerate(group):
                self._finish_or_activate(req, free[r], int(toks[r]), finished)
        return finished

    # --------------------------------------------------------------- decode

    def step(self) -> List[Request]:
        """One pipelined decode step; returns requests finished.

        Dispatches the next decode (or draft+verify) root immediately, then
        consumes the OLDEST in-flight step's token transfer only once the
        ring holds ``pipeline_depth`` entries — so with depth D the device
        runs up to D steps ahead of the host's emission/free bookkeeping.
        Depth 1 reproduces the unpipelined dispatch->sync sequence exactly.
        At most one D2H transfer is consumed per call."""
        if (self.spec is not None and self.spec.dynamic_k
                and self._ring):
            # Per-row window feedback: step N+1's k_row depends on step N's
            # acceptance, so dynamic-k speculation runs the ring at depth 1.
            self._drain_ring()
        if self.spec is not None:
            self._dispatch_spec()
        else:
            self._dispatch_decode()
        if len(self._ring) >= self.pipeline_depth:
            self._consume_one()
        return self._pop_finished()

    def drain(self) -> List[Request]:
        """Consume every in-flight step (one D2H each, oldest first) and
        return all newly finished requests.  The engine calls this before
        any host bookkeeping that must see a synced view — admission,
        defrag, dynamic-k — and callers may use it to flush the tail."""
        self._drain_ring()
        return self._pop_finished()

    def _drain_ring(self) -> None:
        if self.obs.enabled and self._ring:
            self.obs.on_drain(len(self._ring))
        while self._ring:
            self._consume_one()

    def _pop_finished(self) -> List[Request]:
        out, self._pending_finished = self._pending_finished, []
        return out

    def _host_inputs(self):
        """Device-resident (host_keep, temps, eos[, k_row]) for dispatch,
        rebuilt only when admission/finish bookkeeping dirtied them."""
        if self._host_dirty:
            # Explicit device_put (guard-sanctioned; sharded when meshed).
            row = self._sh.row if self._sh is not None else None
            self._keep_dev = jax.device_put(self.active, row)
            self._temps_dev = jax.device_put(self.temps, row)
            self._eos_dev = jax.device_put(self._eos, row)
            if self.spec is not None:
                self._k_row_dev = jax.device_put(self._k_row, row)
            self._host_dirty = False
        return self._keep_dev, self._temps_dev, self._eos_dev

    def _dispatch_decode(self) -> None:
        """Launch one decode root and ring its token future (no sync)."""
        t0 = time.perf_counter()
        mask = self.active.copy()
        with self._guard(), self.obs.span("serving.dispatch.decode"):
            host_keep, temps, eos = self._host_inputs()
            if self.paged:
                (sampled, self.kv.pools, self.cache_len, self.budget_dev,
                 self.key_data, self._active_dev) = self._decode(
                    self.params, self.kv.pools, self.kv.table_device(),
                    self.last_token, self.cache_len, self.budget_dev,
                    self.key_data, self._active_dev, host_keep, temps, eos,
                )
            else:
                (sampled, self.cache, self.cache_len, self.budget_dev,
                 self.key_data, self._active_dev) = self._decode(
                    self.params, self.cache, self.last_token, self.cache_len,
                    self.budget_dev, self.key_data, self._active_dev,
                    host_keep, temps, eos,
                )
        self.last_token = sampled
        self._ring.append(_InFlight(sampled, mask,
                                    time.perf_counter() - t0))
        if self.obs.enabled:
            self._obs_dispatch("decode", mask)

    def _dispatch_spec(self) -> None:
        """Launch one speculative step (fused draft-K root + chunk-verify
        root) and ring its packed committed-token future (no sync)."""
        t0 = time.perf_counter()
        mask = self.active.copy()
        with self._guard():
            host_keep, temps, eos = self._host_inputs()
            k_row = self._k_row_dev

            with self.obs.span("serving.dispatch.spec_draft"):
                (proposals, q_probs, self.draft.pools,
                 self.draft.key_data) = self._spec_draft(
                    self.draft.params, self.draft.pools,
                    self.draft.table_device(),
                    self.last_token, self.cache_len, self.draft.key_data,
                    self._active_dev, host_keep, temps,
                )
            target_cache = self.kv.pools if self.paged else self.cache
            bt = self.kv.table_device() if self.paged else None
            with self.obs.span("serving.dispatch.spec_verify"):
                (pack, target_cache, self.cache_len, self.last_token,
                 self.budget_dev, self.key_data,
                 self._active_dev) = self._spec_verify(
                    self.params, target_cache, bt, self.last_token, proposals,
                    q_probs, self.cache_len, self.budget_dev, self.key_data,
                    self._active_dev, host_keep, temps, eos, k_row,
                )
        if self.paged:
            self.kv.pools = target_cache
        else:
            self.cache = target_cache
        self._ring.append(_InFlight(pack, mask, time.perf_counter() - t0,
                                    spec=True, k_row=self._k_row.copy()))
        if self.obs.enabled:
            self._obs_dispatch("spec", mask)

    def _obs_dispatch(self, kind: str, mask: np.ndarray) -> None:
        """Step-dispatch telemetry: ring depth, live rows, per-shard pool
        occupancy — all host ints the engine already tracks."""
        pool = peaks = None
        if self.paged:
            alloc = self.kv.alloc
            pool = [alloc.in_use(s) for s in range(alloc.num_shards)]
            peaks = self.kv.blocks_per_shard
        self.obs.on_step_dispatch(kind, len(self._ring), int(mask.sum()),
                                  self._ring[-1].dispatch_s, pool, peaks)

    def _consume_one(self) -> None:
        """Sync the oldest in-flight step's tokens (the ONE D2H this step
        ever costs) and run its emission/finish/free bookkeeping, appending
        newly finished requests to the pending list."""
        entry = self._ring.popleft()
        t0 = time.perf_counter()
        with self.obs.span("serving.ring_sync"):
            toks = np.asarray(jax.device_get(entry.tokens))
        t_sync = time.perf_counter() - t0
        self.decode_transfers += 1
        if entry.spec:
            finished = self._commit_spec(entry, toks)
        else:
            finished = self._commit_decode(entry, toks)
        self._pending_finished.extend(finished)
        t_host = time.perf_counter() - t0 - t_sync
        self.step_device_wait_s.append(t_sync)
        self.step_host_s.append(t_host)
        self.step_times.append(entry.dispatch_s + t_sync + t_host)
        if self.obs.enabled:
            self.obs.on_step_consume("spec" if entry.spec else "decode",
                                     t_sync, t_host)

    def _commit_decode(self, entry: _InFlight,
                       toks: np.ndarray) -> List[Request]:
        # A slot live in entry.mask whose request has since been retired
        # (it finished in an OLDER ring entry) carries a garbage token the
        # device either masked or wrote into the slot's still-reserved
        # space: skip it.  FIFO consumption guarantees the converse — a
        # row still live here was device-active at this entry's dispatch.
        live = np.fromiter((r is not None for r in self.slots), bool,
                           self.max_batch)
        adv = entry.mask & live
        self._len_host += adv
        finished: List[Request] = []
        now = time.perf_counter() if self.obs.enabled else 0.0
        for slot, req in enumerate(self.slots):
            if req is None or not adv[slot]:
                continue
            tok = int(toks[slot])
            req.generated.append(tok)
            if self.obs.enabled:
                req.t_last = now
                self.obs.on_commit(req.uid, slot, 1)
            if (req.done or self._len_host[slot] >= self.max_len - 1
                    or tok == self._eos[slot]):
                finished.append(req)
                self._retire_slot(slot)
                if self.obs.enabled:
                    self._obs_finish(req)
        return finished

    def _commit_spec(self, entry: _InFlight,
                     toks: np.ndarray) -> List[Request]:
        k = self.spec.k
        toks_mat = toks[:, : k + 1]
        n_commit, m_acc = toks[:, k + 1], toks[:, k + 2]
        finished: List[Request] = []
        now = time.perf_counter() if self.obs.enabled else 0.0
        for slot, req in enumerate(self.slots):
            if req is None or not entry.mask[slot]:
                continue
            m = int(m_acc[slot])
            k_eff = int(entry.k_row[slot])
            req.spec_proposed += k_eff
            req.spec_accepted += m
            self.spec_proposed += k_eff
            self.spec_accepted += m
            self.spec_step_rows += 1
            if self.obs.enabled:
                self.obs.on_spec_row(k_eff, m)
            self._len_host[slot] += m + 1  # entries committed to cache
            if self.spec.dynamic_k:
                if m == k_eff:
                    self._k_row[slot] = min(k, k_eff + 1)
                elif m == 0:
                    self._k_row[slot] = max(1, k_eff - 1)
                self._host_dirty = True
            done = False
            appended = 0
            base_len = self._len_host[slot] - (m + 1)
            for j in range(int(n_commit[slot])):
                tok = int(toks_mat[slot, j])
                req.generated.append(tok)
                self.spec_committed += 1
                appended += 1
                # Sequential-decode finish semantics: cached length after
                # this token is base_len + j + 1.
                if (req.done or base_len + j + 1 >= self.max_len - 1
                        or tok == self._eos[slot]):
                    done = True
                    break
            if self.obs.enabled and appended:
                req.t_last = now
                self.obs.on_commit(req.uid, slot, appended)
            if done:
                finished.append(req)
                self._retire_slot(slot)
                if self.obs.enabled:
                    self._obs_finish(req)
        return finished

    # ------------------------------------------------------------ telemetry

    def stats(self) -> Dict[str, float]:
        """Decode-step timing summary (seconds) + throughput proxy.

        ``device_wait_*`` is the D2H sync stall per consumed step and
        ``host_*`` the emission/free bookkeeping that follows — the two
        halves the pipeline overlaps with the device's next step."""
        if not self.step_times:
            # Fully-keyed zero snapshot: callers (serve.py, benchmarks,
            # dashboards) index timing keys unconditionally — an engine
            # that never stepped must not crash them or emit NaN.
            return {
                "steps": 0,
                "step_mean_s": 0.0, "step_p50_s": 0.0,
                "step_p90_s": 0.0, "step_p99_s": 0.0,
                "device_wait_mean_s": 0.0, "device_wait_p50_s": 0.0,
                "host_mean_s": 0.0, "host_p50_s": 0.0,
                "pipeline_depth": self.pipeline_depth,
                "live_rows": int(self.active.sum()),
            }
        ts = np.asarray(self.step_times)
        dw = np.asarray(self.step_device_wait_s)
        hb = np.asarray(self.step_host_s)
        n_live = max(1, int(self.active.sum()))
        return {
            "steps": len(ts),
            "step_mean_s": float(ts.mean()),
            "step_p50_s": float(np.percentile(ts, 50)),
            "step_p90_s": float(np.percentile(ts, 90)),
            "step_p99_s": float(np.percentile(ts, 99)),
            "device_wait_mean_s": float(dw.mean()),
            "device_wait_p50_s": float(np.percentile(dw, 50)),
            "host_mean_s": float(hb.mean()),
            "host_p50_s": float(np.percentile(hb, 50)),
            "pipeline_depth": self.pipeline_depth,
            "live_rows": n_live,
        }

    def spec_stats(self) -> Dict[str, float]:
        """Speculative-decoding accounting: acceptance rate and committed
        tokens per live row-step (>= 1.0; the speedup proxy)."""
        if self.spec is None:
            return {}
        return {
            "k": self.spec.k,
            "dynamic_k": bool(self.spec.dynamic_k),
            "proposed": self.spec_proposed,
            "accepted": self.spec_accepted,
            "committed": self.spec_committed,
            "acceptance_rate": self.spec_accepted / max(1, self.spec_proposed),
            "committed_per_row_step":
                self.spec_committed / max(1, self.spec_step_rows),
            "draft_hbm_bytes": self.draft.hbm_bytes(),
        }

    def mesh_shape(self) -> Dict[str, int]:
        """The serving mesh as {dp, tp, devices} ((1, 1, 1) when meshless
        — the layout every sharded stat reduces to on one device)."""
        if self.par is None:
            return {"dp": 1, "tp": 1, "devices": 1}
        m = self.par.mesh
        dp = int(np.prod([m.shape[a] for a in self.par.dp_axes]))
        tp = int(m.shape[self.par.tp_axis]) if self.par.tp_axis else 1
        return {"dp": dp, "tp": tp, "devices": int(m.size)}

    def cache_stats(self) -> Dict[str, float]:
        """Cache memory accounting: HBM bytes (global + per device) +
        live/reserved tokens."""
        live = int((self._len_host * self.active).sum())
        if self.paged:
            s = dict(self.kv.stats(), layout="paged")
        else:
            slab = int(sum(
                leaf.nbytes for leaf in jax.tree.leaves(self.cache)
            ))
            s = {
                "layout": "dense",
                "tokens_capacity": self.max_batch * self.max_len,
                "cache_hbm_bytes": slab,
                "dp_shards": self.dp_shards,
                # The slab shards over its batch dim: each device holds
                # max_batch / dp rows (the whole slab when unsharded).
                "per_device_cache_hbm_bytes": slab // self.dp_shards,
            }
        s["mesh"] = self.mesh_shape()
        s["live_tokens"] = live
        if self.spec is not None:
            s["draft_hbm_bytes"] = self.draft.hbm_bytes()
        return s

    def defrag(self) -> int:
        """Compact live blocks to the lowest pool ids (paged only).
        Returns the number of blocks moved (target + draft pools).

        Drains the step pipeline first: the move map comes from the host
        allocator, which must have consumed every in-flight step's frees
        before permuting the pools (finishes surface from the next public
        step()/_admit()/drain())."""
        if not self.paged:
            return 0
        self._drain_ring()
        moved = len(self.kv.defrag())
        if self.spec is not None:
            moved += len(self.draft.kv.defrag())
        if self.obs.enabled:
            self.obs.on_defrag(moved)
        return moved

    def telemetry_snapshot(self) -> Dict:
        """Full observability snapshot (metrics + trace tail + engine
        stats) — ``{}`` when the engine runs without telemetry."""
        return self.obs.snapshot(self) if self.obs.enabled else {}
