"""Batched, host-sync-free serving engine (continuous batching) over
(compressed) weights, with a paged (block-table) KV cache for attention
models and a dense (max_batch, max_len) slab fallback for everything else.

Slot-based: requests are admitted into free slots, every engine step decodes
one token for all live rows, finished rows free their slot — and their KV
blocks — immediately, so new requests join mid-flight without stalling the
running batch.

Hot-path design (the paper's Eq. 6 payoff is only real if the engine's
memory path keeps up with the factored matmuls):

  * ALL per-slot state lives on device: cache, cache_len, last_token,
    active flags and a per-slot PRNG key array.  The host mirrors only what
    it needs for scheduling, updated from host-side bookkeeping plus the one
    token vector each step already transfers — never by extra syncs.
  * Every jit root DONATES its cache/state buffers (``donate_argnums``), so
    the multi-MB cache is aliased in place by XLA instead of being copied
    every step.
  * ``step()`` is ONE jitted call (decode + batched sampling + device-side
    finish exits for every live row) followed by AT MOST one device->host
    transfer of a sampled token vector.  A row that samples its eos id,
    spends its last budgeted token, or hits the max_len bound clears its
    own active flag on device; the host learns from the tokens it already
    has.
  * The step loop is PIPELINED (``pipeline_depth``, default 2): because
    every finish reason is device-authoritative, step N+1's decode root can
    be dispatched before step N's token transfer is consumed — the engine
    keeps a small ring of in-flight token futures and syncs only the oldest
    when the ring is full, so token emission, slot/block freeing and
    request admission bookkeeping overlap the device's next step instead
    of serializing behind a host round-trip every token.  Depth 1 is
    bit-for-bit the unpipelined engine, and any depth produces identical
    token streams (the device state chain never observes the host's lag).
    Host-mutating events that need a synced view — admission, defrag,
    dynamic-k speculation — drain the ring first (``drain()``).

Paged path (``models.api.cache_layout(model) == "paged"``: pure-attention
stacks — see serving/kvcache/):

  * K/V live in a shared block pool (num_blocks, block_size, ...) instead of
    a dense slab, addressed through per-slot block-table rows, so cache HBM
    scales with pool capacity (live tokens), not max_batch * max_len.
  * Scheduling is a separate policy module (serving/scheduler): by default
    admission reserves only the PROMPT's blocks and the reservation grows
    at block boundaries as the row decodes (allocate-on-demand, so pool
    occupancy tracks live tokens and more rows fit a fixed pool), with
    victim preemption — most-blocks row evicted, resumed by re-prefill or
    host swap-back — when growth or a higher-priority admission runs a
    shard dry.  SLA latency classes queue separately with starvation-free
    aging, and DP placement targets the emptiest shard's sub-pool.
    ``SchedulerConfig(admission="worst_case")`` restores the PR-3 contract
    (prompt + max_new reserved up front; exhaustion surfaces only as
    admission backpressure, never mid-decode).
  * Prefill is CHUNKED: prompts stream into their blocks ``prefill_chunk``
    tokens per engine iteration through one fixed-shape jit root (compiles
    exactly once), interleaved with decode steps so a very long prompt
    cannot stall the running batch.
  * Decode attends through ``kernels/paged_attention`` (Pallas kernel
    streaming exactly the live pages on TPU, jnp gather oracle elsewhere),
    honoring the int8 KV quantization of the dense path.

Dense path (recurrent SSM/RWKV state, token-choice MoE, MLA latents,
enc-dec): the PR-1 design — bucketed batched prefill-admission (pad-safe
models compile once per power-of-two prompt-length bucket; pad-sensitive
ones fall back to exact-length prefill) — now with donated jit roots and the
same device-side EOS exit.

Decode-time nested-lowrank matmuls of compressed layers (dense, attention,
MLP, and the stacked MoE expert FFNs) route through
``kernels/nested_lowrank/ops.py`` (fused Pallas kernel on TPU for
decode-shaped rows, jnp oracle on CPU).

Speculative decoding (``spec_config``, serving/spec/): a higher-compression
NSVD twin of the weights drafts ``k`` tokens per step in one fused jit root
(K+1 sequential cheap decodes over the draft's own paged/dense cache); the
target verifies the whole proposal matrix through the same S>1 chunk-decode
path chunked prefill uses and commits the accepted prefix plus one
correction/bonus token via on-device accept/resample — greedy is
token-identical to non-speculative decode, temperature>0 preserves the
target distribution exactly.  Both caches' per-row lengths roll back to the
committed prefix on device; a step is still exactly two jitted calls and
ONE D2H transfer (the packed committed-token matrix).

Mesh sharding (``parallelism=`` over a launch/mesh.make_serving_mesh DP x TP
mesh): every jit root runs SPMD with explicit in/out NamedShardings
(launch/steps.ServingShardings) — weights TP-sharded via the existing
param_pspecs (factored NSVD layers all-reduce rank-k partials, not
d_model), per-slot state and host-built (B, ...) inputs data-parallel over
slots, the dense slab sharded over its batch dim and the paged block pools
over their block dim with PER-SHARD block id ranges: slot s maps to DP
shard s*dp/max_batch, its reservations come from that shard's range, and
admission/free/defrag/rollback stay host-authoritative per shard
(serving/kvcache).  The donation and one-D2H-per-step contracts are
unchanged — sampled tokens leave via ONE sharded transfer, and a (1, 1)
mesh reproduces the meshless single-device engine bit-for-bit (pinned by
tests/test_sharded_serving.py).  When max_batch does not divide the DP
size, slot/pool sharding falls back to replicated (weights stay TP).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import time
import zlib
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import (
    POISON_TOKEN,
    RootContext,
    ServingShardings,
    named,
    serving_root_registry,
)
from repro.models.api import (
    Model,
    build_model,
    cache_layout,
    prefill_pad_safe,
    serving_cache_pspecs,
)
from repro.obs import NULL_TELEMETRY
from repro.parallel.sharding import Parallelism
from repro.runtime.straggler import StepTimeWatchdog
from repro.serving.faults import (
    FaultPlan,
    FaultPolicy,
    ServingFault,
    ServingFaultHandler,
)
from repro.serving.kvcache import PagedKVCache
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.spec import DraftState, SpecConfig


def _swap_checksum(blocks) -> int:
    """CRC32 chained over a swap payload's host leaves (flatten order is
    deterministic for a fixed pool pytree), so a corrupted copy is caught
    at resume instead of scattering garbage KV back onto the device."""
    crc = 0
    for leaf in jax.tree.leaves(blocks):
        crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
    return crc


@dataclasses.dataclass
class _SwapPayload:
    """A preempted row's KV prefix, swapped to host for a copy-back resume:
    the block rows covering its committed context, plus the row's PRNG key
    so the sampling chain continues where it stopped (temperature streams
    stay identical to an un-preempted run)."""
    n_ctx: int            # committed context length the blocks cover
    n_blocks: int         # leading block count of every ``blocks`` leaf
    blocks: object        # host pytree of per-layer pool block rows
    key_row: np.ndarray   # (2,) uint32 saved sampling-key state
    # CRC32 over the leaves at swap-out time; a mismatch at resume means
    # the host copy was corrupted and the engine falls back to reprefill.
    checksum: Optional[int] = None

    @property
    def nbytes(self) -> int:
        import jax as _jax
        return int(sum(leaf.nbytes for leaf in _jax.tree.leaves(self.blocks)))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    # SLA admission (serving/scheduler): latency class name + queue index.
    latency_class: Optional[str] = None
    class_idx: int = 0
    # Preemption bookkeeping: eviction count, and the host-swapped KV
    # payload when the scheduler resumes by copy-back instead of re-prefill
    # (reprefill resumes instead fold ``generated`` into ``prompt``;
    # ``prompt_absorbed`` counts how many generated tokens the prompt
    # already holds, so a SECOND preemption folds only the new suffix).
    preemptions: int = 0
    prompt_absorbed: int = 0
    swap: Optional[_SwapPayload] = None
    # Fault tolerance (serving/faults): absolute deadline (time.monotonic)
    # for admission-side shedding, the terminal reason (one of
    # faults.FINISH_REASONS; None until the request finishes), and how
    # many poison-quarantine retries this request has burned.
    deadline: Optional[float] = None
    finish_reason: Optional[str] = None
    retries: int = 0
    # Speculative-decoding accounting (spec_config engines only).
    spec_proposed: int = 0
    spec_accepted: int = 0
    # Lifecycle timestamps (time.perf_counter; populated only when the
    # engine runs with telemetry enabled — see repro.obs).
    t_submit: float = 0.0
    t_first: float = 0.0
    t_last: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def prefix_len(self) -> int:
        """Tokens admission must cover: the prompt (re-prefill resumes
        fold generated tokens into it) or the swapped context length."""
        return self.swap.n_ctx if self.swap is not None else len(self.prompt)

    @property
    def acceptance_rate(self) -> float:
        return self.spec_accepted / max(1, self.spec_proposed)


@dataclasses.dataclass
class _PrefillTask:
    """A request streaming its prompt into reserved blocks, chunk by chunk."""
    req: Request
    slot: int
    pos: int = 0  # next prompt position to feed


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unconsumed decode step in the pipeline ring.

    ``tokens`` is the step's device-resident result (the sampled token
    vector, or the packed [tokens|n_commit|m] matrix in speculative mode);
    ``mask`` snapshots the host's active view at dispatch so consumption
    attributes tokens to the rows that were live then.  FIFO consumption
    keeps the invariant that a row live on the host at consume time was
    device-active at this entry's dispatch (every device exit has a host
    twin that fires when the triggering entry is consumed — earlier in the
    ring by construction)."""
    tokens: jax.Array
    mask: np.ndarray
    dispatch_s: float
    spec: bool = False
    k_row: Optional[np.ndarray] = None


_PIPELINE_DEPTH_ENV = "REPRO_SERVING_PIPELINE_DEPTH"
_TRANSFER_GUARD_ENV = "REPRO_SERVING_TRANSFER_GUARD"


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        max_batch: int = 8,
        max_len: int = 512,
        seed: int = 0,
        bucket_min: int = 16,
        paged: Optional[bool] = None,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        prefill_chunk: int = 64,
        eos_id: Optional[int] = None,
        kv_quant: bool = False,
        spec_config: Optional[SpecConfig] = None,
        parallelism: Optional[Parallelism] = None,
        pipeline_depth: Optional[int] = None,
        transfer_guard: Optional[bool] = None,
        telemetry=None,
        sched_config: Optional[SchedulerConfig] = None,
        faults: Optional[FaultPlan] = None,
        fault_policy: Optional[FaultPolicy] = None,
    ):
        # Observability (repro.obs.Telemetry, or the shared no-op).  All
        # hooks consume host bookkeeping + the packed D2H word the step
        # already transfers — never an extra device sync — and per-row
        # work is gated on ``self.obs.enabled`` so the default path stays
        # no-op (pinned by tests/test_observability.py).
        self.obs = telemetry if telemetry is not None else NULL_TELEMETRY
        self._obs_blocked: set = set()
        if self.obs.enabled and spec_config is not None:
            self.obs.spec_meta.setdefault("k", spec_config.k)
            if spec_config.draft_ratio is not None:
                self.obs.spec_meta.setdefault("draft_ratio",
                                              spec_config.draft_ratio)
        if pipeline_depth is None:
            pipeline_depth = int(os.environ.get(_PIPELINE_DEPTH_ENV, "2"))
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}"
            )
        self.pipeline_depth = pipeline_depth
        if transfer_guard is None:
            transfer_guard = os.environ.get(
                _TRANSFER_GUARD_ENV, "0").lower() not in ("", "0", "false")
        self.transfer_guard = bool(transfer_guard)
        par = (parallelism
               if parallelism is not None and parallelism.active else None)
        self.par = par
        if par is not None:
            # Rebuild the model facade against the mesh so its internal
            # activation constraints (batch DP, logits TP) apply inside
            # every root; params/caches are plain pytrees, so the rebuilt
            # facade is interchangeable with the caller's.
            model = build_model(model.cfg, par)
            dp_size = int(np.prod([par.mesh.shape[a] for a in par.dp_axes]))
            # Slots (and with them the paged pools' block ranges) shard
            # over DP only when they divide it; otherwise per-slot state
            # and the cache stay replicated while weights keep TP.
            self.dp_shards = dp_size if max_batch % dp_size == 0 else 1
        else:
            self.dp_shards = 1
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id

        layout = cache_layout(model)
        self.paged = (layout == "paged") if paged is None else bool(paged)
        if self.paged and layout != "paged":
            raise ValueError(
                f"model {model.cfg.name!r} has cache layout {layout!r}; "
                "paging requires a pure-attention cache (models.api.cache_layout)"
            )
        self.spec = spec_config
        if self.spec is not None and layout != "paged":
            raise ValueError(
                f"model {model.cfg.name!r} has cache layout {layout!r}; "
                "speculative decoding needs pure-attention caches (chunk "
                "verification and length rollback have no recurrent/MoE/MLA "
                "form)"
            )

        # Scheduling policy (serving/scheduler): per-class admission
        # queues, on-demand vs worst-case block reservation, preemption
        # + resume mode, DP placement, and decode-row dispatch order.
        self.sched = Scheduler(sched_config)
        if (self.sched.resume_mode == "swap" and spec_config is not None):
            raise ValueError(
                "resume='swap' is unsupported with speculative decoding "
                "(the draft pool's swapped prefix has no catch-up path); "
                "use resume='reprefill'"
            )

        # Device-resident state (never read back except the sampled tokens).
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.budget_dev = jnp.zeros((max_batch,), jnp.int32)
        self.key_data = jax.random.key_data(
            jax.random.split(jax.random.key(seed), max_batch)
        )
        # Per-request key derivation (see Request.key_data).
        self._base_key = jax.random.key(seed)
        self._draft_base_key = (jax.random.key(spec_config.seed)
                                if spec_config is not None else None)
        self._active_dev = jnp.zeros((max_batch,), bool)

        # Host mirrors for scheduling (updated by bookkeeping + the step's
        # own token transfer, not extra syncs).
        self.active = np.zeros((max_batch,), bool)
        self.temps = np.zeros((max_batch,), np.float32)
        self._eos = np.full((max_batch,), -1, np.int32)
        self._len_host = np.zeros((max_batch,), np.int64)
        # On-demand growth bookkeeping: ``_dev_len`` conservatively mirrors
        # each row's DEVICE cache length at dispatch time (host ``_len_host``
        # lags by the pipeline ring), so coverage targets never undershoot
        # a write the device is about to make.  ``_stalled`` rows are live
        # but frozen (host_keep=False) because their shard ran dry with
        # preemption disabled — they resume exactly where they froze once
        # blocks free up.
        self._dev_len = np.zeros((max_batch,), np.int64)
        self._stalled = np.zeros((max_batch,), bool)
        # Scheduler lifecycle counters + occupancy accumulators (plain
        # host ints/floats; surfaced by scheduler_stats() and the bench).
        self.sched_events: Dict[str, int] = {
            "preemptions": 0, "swap_bytes": 0, "grown_blocks": 0,
            "resumes": 0, "stalls": 0,
        }
        self._occ_live_frac_sum = 0.0
        self._occ_samples = 0
        self._occ_rows_sum = 0
        self._occ_rows_steps = 0

        # Fault injection + degradation (serving/faults).  The plan is a
        # pure chaos surface consumed at explicit injection sites —
        # without one, every site is a single ``is None`` check.  The
        # policy/handler own quarantine-vs-retry dispositions; the
        # watchdog (built only when chaos/policy is requested) classifies
        # per-step durations and enforces the hard step timeout.
        self._faults = faults
        self._fault_policy = (fault_policy if fault_policy is not None
                              else FaultPolicy())
        self._handler = ServingFaultHandler(self._fault_policy)
        self._watchdog = (StepTimeWatchdog(self._fault_policy.straggler)
                          if faults is not None or fault_policy is not None
                          else None)
        # Chaos-variant roots (a trailing poison input on the steady
        # sampling roots) are built only when the plan can poison logits;
        # otherwise the roots are byte-identical to a fault-free engine's.
        self._chaos = faults is not None and faults.has("poison_logits")
        self._poison_zero = None
        self._step_idx = 0  # monotonic dispatch counter (decode/spec)
        # Backoff-parked poison retries: (ready_step, Request).
        self._parked: List[Tuple[int, Request]] = []
        self._has_deadlines = False
        self._draining = False
        self._closed = False
        self._draft_dead = False
        self._draft_off_until = 0
        # uid -> Request for every terminal exit (normal or aborted), so
        # finish_reason accounting can never miss a path.
        self.finished_requests: Dict[int, Request] = {}
        self.fault_events: Dict[str, int] = {
            "quarantined": 0, "retried": 0, "shed": 0, "cancelled": 0,
            "swap_fallbacks": 0, "draft_kills": 0, "draft_reenables": 0,
            "straggler_slow": 0, "straggler_trips": 0,
        }

        # Device-resident copies of the loop-invariant host inputs
        # (host_keep / temps / eos [/ k_row]).  They only change on slot
        # (re)admission or a finish, so dispatch reuses the cached arrays
        # instead of re-uploading three (B,) host arrays every step; any
        # bookkeeping that mutates them flips ``_host_dirty``.
        self._host_dirty = True
        self._keep_dev = None
        self._temps_dev = None
        self._eos_dev = None
        self._k_row_dev = None
        self._order_dev = None

        # Pipeline ring of dispatched-but-unconsumed steps, plus finished
        # requests produced by internal drains (handed out by the next
        # public step()/_admit()/drain()).
        self._ring: deque[_InFlight] = deque()
        self._pending_finished: List[Request] = []

        self.slots: List[Optional[Request]] = [None] * max_batch
        self._prefilling: List[_PrefillTask] = []
        self._uid = itertools.count()
        # Free slots are handed out in the order they FREED, not by index.
        # Token streams never depend on slot choice (sampling keys are
        # per-REQUEST, see Request.key_data), but freed-order assignment
        # keeps slot/pool layouts closer across pipeline depths — at depth
        # 2 two finishes can surface from one drain, and an index-ordered
        # free list would swap their successors' slots relative to depth 1.
        self._free_clock = itertools.count()
        self._freed_at = np.arange(max_batch, dtype=np.int64) - max_batch
        self._bucketed = prefill_pad_safe(model)

        if self.paged:
            self.kv = PagedKVCache(
                model, max_batch, max_len, block_size=block_size,
                num_blocks=num_blocks, kv_quant=kv_quant,
                dp_shards=self.dp_shards, par=par,
            )
            self.prefill_chunk = prefill_chunk
            self._sh = (ServingShardings(par, params, self.kv.shardings,
                                         max_batch)
                        if par is not None else None)
            if par is not None:
                self.params = params = jax.device_put(params,
                                                      self._sh.params)
                # Cached block-table mirror must be born with the roots'
                # expected (B, M) sharding (see PagedKVCache.table_device).
                self.kv.table_sharding = self._sh.mat
        else:
            self.cache = model.init_cache(max_batch, max_len,
                                          kv_quant=kv_quant)
            self._sh = None
            if par is not None:
                cache_sh = named(
                    serving_cache_pspecs(model, par, max_batch=max_batch,
                                         max_len=max_len,
                                         kv_quant=kv_quant,
                                         shapes=self.cache),
                    par.mesh,
                )
                self._sh = ServingShardings(par, params, cache_sh,
                                            max_batch)
                self.params = params = jax.device_put(params,
                                                      self._sh.params)
                self.cache = jax.device_put(self.cache, cache_sh)
            self._buckets = self._make_buckets(bucket_min, max_len)

        # All jit roots come from the serving root registry (the same specs
        # the static auditor traces): builder, donate_argnums and sharding
        # hook live in ONE place, so an audited contract is by construction
        # the contract the engine runs.
        self._ctx = RootContext(
            model=model, max_batch=max_batch, max_len=max_len,
            kv_quant=kv_quant, prefill_chunk=prefill_chunk,
            block_size=block_size,
            num_blocks=self.kv.num_blocks if self.paged else None,
            spec_k=spec_config.k if spec_config is not None else 4,
            bucketed=self._bucketed, dp_shards=self.dp_shards,
            chaos=self._chaos,
        )
        self._roots = {r.name: r for r in serving_root_registry(
            "paged" if self.paged else "dense",
            spec=spec_config is not None)}
        if self.paged:
            self._decode = self._root("paged_decode")
            self._chunk_step = self._root("paged_prefill_chunk")
        else:
            self._decode = self._root("decode")
            self._prefill = self._root("prefill_admit")

        if self._sh is not None:
            # Per-slot device state lives sharded from birth so the roots'
            # donated buffers alias in place (resharding would copy).
            self.cache_len = jax.device_put(self.cache_len, self._sh.row)
            self.last_token = jax.device_put(self.last_token, self._sh.row)
            self.budget_dev = jax.device_put(self.budget_dev, self._sh.row)
            self.key_data = jax.device_put(self.key_data, self._sh.mat)
            self._active_dev = jax.device_put(self._active_dev,
                                              self._sh.row)

        if self.spec is not None:
            draft_params = self.spec.draft_params
            dparams_sh = None
            if self._sh is not None:
                # Draft weights follow the same TP rules (factored leaves
                # shard by the u/v orientation rules); the draft cache
                # inherits the target's shardings by construction.
                dparams_sh = self._sh.tree(draft_params)
                draft_params = jax.device_put(draft_params, dparams_sh)
            self.draft = DraftState(
                model, draft_params, max_batch, max_len,
                paged=self.paged, block_size=block_size,
                num_blocks=num_blocks, kv_quant=kv_quant,
                seed=self.spec.seed, dp_shards=self.dp_shards, par=par,
                cache_shardings=(None if self.paged or self._sh is None
                                 else self._sh.cache),
                key_sharding=self._sh.mat if self._sh else None,
            )
            if self.paged and self._sh is not None:
                self.draft.kv.table_sharding = self._sh.mat
            self._spec_draft = self._root("spec_draft", dparams_sh)
            self._spec_verify = self._root("spec_verify", dparams_sh)
            self._draft_prefill = self._root("draft_prefill", dparams_sh)
            # Per-row speculation windows (all k unless dynamic_k shrinks).
            self._k_row = np.full((max_batch,), self.spec.k, np.int32)
            self.spec_proposed = 0
            self.spec_accepted = 0
            self.spec_committed = 0
            self.spec_step_rows = 0
        else:
            self.draft = None

        # Telemetry: per-consumed-step wall times (dispatch + D2H sync +
        # host bookkeeping) plus the sync/host breakdown the benchmark
        # reports (device wait vs host-side work per step).
        self.step_times: List[float] = []
        self.step_device_wait_s: List[float] = []
        self.step_host_s: List[float] = []
        self.decode_transfers = 0

    @staticmethod
    def _jit(fn, donate, shardings=None):
        """jit a serving root: donation always; explicit in/out shardings
        when the engine runs on a mesh (pinning donated-buffer aliasing and
        step-to-step layout stability)."""
        if shardings is None:
            return jax.jit(fn, donate_argnums=donate)
        in_sh, out_sh = shardings
        return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate)

    def _root(self, name: str, draft_params_sh=None):
        """Build one jitted serving root from its registry spec."""
        spec = self._roots[name]
        sh = (spec.shardings(self._sh, self._ctx, draft_params_sh)
              if self._sh is not None else None)
        return self._jit(spec.build(self._ctx), spec.donate, sh)

    def _guard(self):
        """Steady-state transfer guard (opt-in, ``transfer_guard=`` or
        REPRO_SERVING_TRANSFER_GUARD=1): the decode/spec dispatch path runs
        under jax.transfer_guard("disallow"), so any IMPLICIT device<->host
        transfer — a stray numpy input, a silent sync — raises instead of
        silently serializing the pipeline.  The engine's own sanctioned
        movements (cached host-input rebuilds, block-table mirror uploads)
        are explicit jax.device_put calls, and the per-step token readback
        is an explicit jax.device_get outside the guarded region."""
        if self.transfer_guard:
            return jax.transfer_guard("disallow")
        return contextlib.nullcontext()

    # --------------------------------------------------------------- API

    @property
    def queue(self):
        """Admission-queue view (the scheduler): truthy while requests
        wait, ``len()`` for the count — the pre-scheduler deque surface."""
        return self.sched

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               temperature: float = 0.0,
               eos_id: Optional[int] = None,
               latency_class: Optional[str] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue one request; returns its uid.  ``latency_class`` names a
        configured SchedulerConfig.priority_class (None = the lowest).
        ``deadline_s`` is a relative admission deadline: a request still
        QUEUED when it expires is shed (finish_reason='deadline') instead
        of admitted — activated rows always run to completion."""
        if self._closed:
            raise RuntimeError("submit() on a closed engine")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be positive, got {max_new_tokens}"
            )
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds max_len-1={self.max_len - 1}"
            )
        if self.paged:
            # A request whose worst case exceeds one DP shard's sub-pool
            # (== the total pool when unsharded) could never finish: under
            # worst-case admission it would stall the queue head forever,
            # and under on-demand growth it could preempt every other row
            # and STILL run the shard dry mid-decode — fail fast at submit
            # under both policies (this check is also what guarantees a
            # preempted request can always be resumed: its grown prefix
            # stays within one shard's capacity).
            need = min(self.max_len, len(prompt) + max_new_tokens)
            n_blocks = self.kv.blocks_for(need)
            if n_blocks > self.kv.blocks_per_shard:
                raise ValueError(
                    f"request needs {n_blocks} blocks worst-case "
                    f"(prompt {len(prompt)} + max_new {max_new_tokens}) but "
                    f"a pool shard only has {self.kv.blocks_per_shard} "
                    f"(num_blocks={self.kv.num_blocks} over "
                    f"{self.kv.dp_shards} DP shard(s))"
                )
        req = Request(next(self._uid), prompt, max_new_tokens, temperature,
                      eos_id if eos_id is not None else self.eos_id,
                      latency_class=latency_class,
                      class_idx=self.sched.class_index(latency_class))
        if deadline_s is not None:
            if deadline_s <= 0:
                raise ValueError(
                    f"deadline_s must be positive, got {deadline_s}")
            req.deadline = time.monotonic() + deadline_s
            self._has_deadlines = True
        if self.obs.enabled:
            req.t_submit = time.perf_counter()
            self.obs.on_submit(req.uid, len(prompt), max_new_tokens)
        self.sched.submit(req)
        return req.uid

    def _request_keys(self, uids, draft: bool = False) -> np.ndarray:
        """(N, 2) uint32 per-request PRNG key data — fold_in(seed, uid),
        one vmapped dispatch per admission group (keys depend only on the
        engine/draft seed and the uid, never on scheduling)."""
        base = self._draft_base_key if draft else self._base_key
        return np.asarray(jax.vmap(
            lambda u: jax.random.key_data(jax.random.fold_in(base, u))
        )(jnp.asarray(uids, jnp.uint32)))

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue + prefills + slots drain.  uid -> generated.

        Admission runs only when it could actually progress (see
        ``_admission_could_progress``) — the host checks are free, and
        calling ``_admit`` while the batch is full or the pool is
        backpressured (the saturated regimes) would drain the step
        pipeline every iteration and forfeit exactly the overlap it
        exists for; a slot/block freed by an in-flight step surfaces when
        step() consumes it, one iteration later."""
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if self._parked:
                self._unpark()
            if self._draining:
                self._shed_shutdown()
            if self._has_deadlines and self.sched:
                self._shed_expired()
            for req in self._pop_finished():  # shed/cancelled surface here
                finished[req.uid] = req.generated
            if self._admission_could_progress():
                for req in self._admit():
                    finished[req.uid] = req.generated
            if not (self.active & ~self._stalled).any():
                # The host may only THINK rows are done pending in-flight
                # transfers: flush the ring, then re-check.  Draining may
                # also free blocks a stalled row was waiting on — retry
                # growth before concluding anything about liveness.
                for req in self.drain():
                    finished[req.uid] = req.generated
                if self.paged and self._stalled.any():
                    self._ensure_coverage()
                if not (self.active & ~self._stalled).any():
                    if not self.active.any():
                        if not self.sched and not self._prefilling:
                            if not self._parked:
                                break
                            # Only backoff-parked retries remain and the
                            # device is empty: fast-forward the dispatch
                            # counter to the earliest ready step instead
                            # of spinning empty iterations (the loop-top
                            # _unpark requeues them next pass).
                            self._step_idx = max(
                                self._step_idx,
                                min(s for s, _ in self._parked))
                            continue
                        continue
                    if self._prefilling or self._admission_could_progress():
                        continue  # prefill/admission can still free or fill
                    raise RuntimeError(
                        "KV pool deadlock: every live row is stalled on an "
                        "exhausted block pool with preemption disabled and "
                        "nothing left to drain — enable preemption "
                        "(SchedulerConfig.preempt) or use admission="
                        "'worst_case'"
                    )
            for req in self.step():
                finished[req.uid] = req.generated
        return finished

    # ------------------------------------------------------------- admission

    def _admit(self) -> List[Request]:
        """Admit queued requests (returns any that finish at admission,
        plus any finished by the pipeline drain admission requires).

        Drain discipline: admission reads the host's free-slot / block
        views and scatters fresh per-slot state, so every in-flight step
        must be consumed first — the ring is empty while the prefill roots
        run, and no in-flight entry ever straddles a slot's change of
        occupant."""
        self._drain_ring()
        finished = self._pop_finished()
        finished.extend(
            self._admit_paged() if self.paged else self._admit_dense()
        )
        return finished

    def _obs_finish(self, req: Request) -> None:
        """Report one finished request (TTFT/TPOT from its timestamps)."""
        n = len(req.generated)
        ttft = req.t_first - req.t_submit if req.t_submit else 0.0
        tpot = ((req.t_last - req.t_first) / (n - 1)
                if n > 1 and req.t_last > req.t_first else 0.0)
        self.obs.on_finish(req.uid, n, ttft, tpot)

    def _finish_or_activate(self, req: Request, slot: int, tok: int,
                            finished: List[Request]) -> None:
        """Shared post-prefill bookkeeping for a request's first token."""
        req.slot = slot
        req.generated.append(tok)
        if self.obs.enabled:
            req.t_first = req.t_last = time.perf_counter()
            self.obs.on_first_token(req.uid, slot,
                                    req.t_first - req.t_submit
                                    if req.t_submit else 0.0)
        self.temps[slot] = req.temperature
        self._eos[slot] = -1 if req.eos_id is None else req.eos_id
        self._len_host[slot] = len(req.prompt)
        self._dev_len[slot] = len(req.prompt)
        self._stalled[slot] = False
        self._host_dirty = True
        if self.spec is not None:
            self._k_row[slot] = self.spec.k  # fresh speculation window
        if (req.done or self._len_host[slot] >= self.max_len - 1
                or tok == self._eos[slot]):
            finished.append(req)
            self._mark_finished(req)
            self._retire_slot(slot)
            if self.obs.enabled:
                self._obs_finish(req)
        else:
            self.slots[slot] = req
            self.active[slot] = True

    # ---- paged: reserve blocks, stream prompts chunkwise

    def _free_slots(self, busy=frozenset()) -> List[int]:
        """Free slots in the order they freed (see ``_freed_at``)."""
        return sorted(
            (i for i in range(self.max_batch)
             if not self.active[i] and i not in busy),
            key=lambda i: self._freed_at[i],
        )

    def _retire_slot(self, slot: int) -> None:
        """Shared retirement bookkeeping for EVERY finish path (admission
        finishes and both commit paths): release the slot, invalidate the
        cached host inputs, stamp the freed-order clock, free KV blocks."""
        req = self.slots[slot]
        if req is not None:
            # A retired uid must be able to re-arm the preempt_ready
            # signal if it is ever re-blocked (and the set must not grow
            # unboundedly over a long-running engine).
            self._obs_blocked.discard(req.uid)
        self.slots[slot] = None
        self.active[slot] = False
        self._stalled[slot] = False
        self._dev_len[slot] = 0
        self._host_dirty = True
        self._freed_at[slot] = next(self._free_clock)
        if self.paged:
            self.kv.free(slot)  # blocks reusable immediately
        if self.spec is not None:
            self.draft.free(slot)

    def _admission_could_progress(self) -> bool:
        """Cheap host-side check gating _admit() calls from run(): a
        prefill is mid-flight, or the scheduler head could plausibly land
        in a free slot (paged: and its admission blocks — prompt-only
        under on-demand, worst case under worst_case — fit today's free
        blocks, target AND draft pools), or an SLA preemption could make
        the room — otherwise calling _admit would drain the step pipeline
        every iteration just to back off again.  A blocked round ages the
        waiting class-heads (starvation-free admission)."""
        if self._prefilling:
            return True
        head = self.sched.head()
        if head is None:
            return False
        blocked = bool(self.active.all())
        if not blocked and self.paged:
            n_blocks = self.kv.blocks_for(
                self.sched.admit_tokens(head, self.max_len))
            blocked = self.kv.alloc.free_blocks() < n_blocks
            if not blocked and self.spec is not None:
                blocked = self.draft.kv.alloc.free_blocks() < n_blocks
        if not blocked:
            return True
        if (self.paged and self.sched.preempt
                and self._outranked_victims(head)):
            return True  # SLA preemption will make room in _admit
        self.sched.note_blocked()
        return False

    def _outranked_victims(self, head: Request):
        """(slot, blocks, class_idx) of live rows the head's latency class
        STRICTLY outranks — the only rows SLA admission may evict (equal
        class blocks on backpressure, never thrash)."""
        return [(s, len(self.kv.alloc.owned_by(s)), r.class_idx)
                for s, r in enumerate(self.slots)
                if r is not None and r.class_idx > head.class_idx]

    def _admit_paged(self) -> List[Request]:
        finished: List[Request] = []
        busy = {t.slot for t in self._prefilling}
        while True:
            req = self.sched.head()
            if req is None:
                break
            if self._take_fault("alloc_fail") is not None:
                # Injected allocator failure: admission backs off this
                # round exactly like a dry pool and retries next round —
                # never the idle-shard RuntimeError a real undersized
                # pool raises.
                break
            need = self.sched.admit_tokens(req, self.max_len)
            free = [s for s in self._free_slots(busy)]
            if not free:
                # Batch full: a strictly-outranked live row may be evicted
                # for the head (the ring is drained — _admit's contract).
                victim = (self.sched.pick_victim(self._outranked_victims(req))
                          if self.sched.preempt else None)
                if victim is None:
                    break
                self._preempt(victim, "priority")
                continue
            # Placement: the scheduler orders candidate slots by their DP
            # shard's headroom (emptiest sub-pool first; freed-order within
            # a shard, which IS the old handout when unsharded).  Block
            # reservations are per shard (slot s -> shard s*dp/max_batch),
            # so the head tries every free slot — different slots may land
            # on shards with different headroom.
            slot = None
            for cand in self.sched.slot_order(free, self.kv, self._freed_at):
                if not self.kv.reserve(cand, need):
                    if self.kv.alloc.in_use(self.kv.slot_shard(cand)) == 0:
                        raise RuntimeError(
                            f"request {req.uid} needs "
                            f"{self.kv.blocks_for(need)} blocks but an idle "
                            f"pool shard only has "
                            f"{self.kv.blocks_per_shard}"
                        )
                    continue
                if (self.spec is not None
                        and not self.draft.reserve(cand, need)):
                    # Draft pool is reserved in lockstep with the target's:
                    # on failure roll the target reservation back and try
                    # the next shard (or wait).
                    self.kv.free(cand)
                    continue
                slot = cand
                break
            if slot is None:
                # Every shard exhausted.  SLA preemption first (strictly
                # lower-priority victims only), then FIFO backpressure:
                # flag the live row holding the most blocks as
                # preempt-ready ONCE per blocked request — the victim the
                # pool-dry preemption path actually picks.
                if self.sched.preempt:
                    victim = self.sched.pick_victim(
                        self._outranked_victims(req))
                    if victim is not None:
                        self._preempt(victim, "priority")
                        continue
                if self.obs.enabled and req.uid not in self._obs_blocked:
                    self._obs_blocked.add(req.uid)
                    owners = {t.slot: t.req for t in self._prefilling}
                    owners.update({s: r for s, r in enumerate(self.slots)
                                   if r is not None})
                    cand = max(owners,
                               key=lambda s: len(self.kv.alloc.owned_by(s)),
                               default=None)
                    if cand is not None:
                        self.obs.on_preempt_ready(owners[cand].uid, cand)
                break
            self.sched.pop_head()
            self._obs_blocked.discard(req.uid)
            busy.add(slot)
            if self.obs.enabled:
                self.obs.on_admit(req.uid, slot,
                                  time.perf_counter() - req.t_submit)
            if req.swap is not None:
                self._resume_swap(req, slot)
            else:
                if req.preemptions:
                    self.sched_events["resumes"] += 1
                    if self.obs.enabled:
                        self.obs.on_resume(req.uid, slot, "reprefill")
                self._prefilling.append(_PrefillTask(req, slot))
        if self._prefilling:
            finished.extend(self._prefill_tick())
        return finished

    def _prefill_tick(self) -> List[Request]:
        """Advance every in-flight prefill by ONE chunk (single jit call).
        run() interleaves these ticks with decode steps, so long prompts
        stream in without stalling live rows."""
        c = self.prefill_chunk
        r_rows = self.max_batch
        tasks = self._prefilling[:r_rows]
        tokens = np.zeros((r_rows, c), np.int32)
        starts = np.zeros((r_rows,), np.int32)
        nvalid = np.ones((r_rows,), np.int32)
        fslots = np.full((r_rows,), self.max_batch, np.int32)  # pad = dropped
        budgets = np.zeros((r_rows,), np.int32)
        rkeys = np.zeros((r_rows, 2), np.uint32)
        d_keys = (np.zeros((r_rows, 2), np.uint32)
                  if self.spec is not None else None)
        temps = np.zeros((r_rows,), np.float32)
        bt_rows = np.full((r_rows, self.kv.max_blocks_per_row), -1, np.int32)
        d_bt = (np.full((r_rows, self.kv.max_blocks_per_row), -1, np.int32)
                if self.spec is not None else None)
        fin: List[tuple] = []
        for r, task in enumerate(tasks):
            p = task.req.prompt
            n = min(len(p) - task.pos, c)
            if self.obs.enabled and task.pos == 0:
                self.obs.on_first_chunk(task.req.uid, task.slot)
            tokens[r, :n] = p[task.pos: task.pos + n]
            starts[r] = task.pos
            nvalid[r] = n
            temps[r] = task.req.temperature
            bt_rows[r] = self.kv.table_np[task.slot]
            if d_bt is not None:
                d_bt[r] = self.draft.kv.table_np[task.slot]
            task.pos += n
            if task.pos >= len(p):
                fslots[r] = task.slot
                # Budget after the first sampled token: fresh requests have
                # generated == []; a reprefill-resumed request's prompt
                # already contains its generated tokens, so its budget is
                # what remains AFTER re-sampling the next one.
                budgets[r] = max(0, task.req.max_new_tokens
                                 - len(task.req.generated) - 1)
                fin.append((r, task))
        if fin:
            # Per-request sampling chains for the finishing rows (one
            # batched fold_in dispatch; see Request/_request_keys).
            uids = [t.req.uid for _, t in fin]
            fr = [r for r, _ in fin]
            rkeys[fr] = self._request_keys(uids)
            if d_keys is not None:
                d_keys[fr] = self._request_keys(uids, draft=True)
        tok_dev, starts_dev = jnp.asarray(tokens), jnp.asarray(starts)
        fslots_dev = jnp.asarray(fslots)
        (first, self.kv.pools, self.cache_len, self.last_token,
         self.budget_dev, self.key_data, self._active_dev) = self._chunk_step(
            self.params, self.kv.pools, jnp.asarray(bt_rows),
            tok_dev, starts_dev, jnp.asarray(nvalid),
            fslots_dev, jnp.asarray(budgets), jnp.asarray(rkeys),
            self.cache_len, self.last_token, self.budget_dev, self.key_data,
            jnp.asarray(temps), self._active_dev,
        )
        if self.spec is not None:
            # Stream the same chunk into the draft pools (its own block
            # tables; lengths/last tokens are shared with the target) and
            # reset finishing rows' draft keys to their requests' chains.
            self.draft.pools, self.draft.key_data = self._draft_prefill(
                self.draft.params, self.draft.pools, jnp.asarray(d_bt),
                tok_dev, starts_dev, fslots_dev, self.draft.key_data,
                jnp.asarray(d_keys),
            )
        finished: List[Request] = []
        if fin:
            toks = np.asarray(jax.device_get(first))
            done_tasks = {id(t) for _, t in fin}
            for r, task in fin:
                self._finish_or_activate(task.req, task.slot, int(toks[r]),
                                         finished)
            self._prefilling = [t for t in self._prefilling
                                if id(t) not in done_tasks]
        return finished

    # ---- on-demand growth + preemption (serving/scheduler decisions)

    def _ensure_coverage(self) -> None:
        """Grow every live row's block reservation to cover its next
        dispatch (one token, or the k+1 speculative chunk) — the
        allocate-on-demand half of the scheduler contract.  Growth is
        alloc-only (it appends table entries; the dirty table mirror
        re-uploads at the next dispatch), so it is safe with steps in
        flight.  A row whose shard is dry either stalls (preemption off:
        frozen on device until blocks free) or triggers victim preemption
        (ring drained first — PR 5 drain discipline)."""
        if not self.paged or not self.sched.on_demand:
            return
        look = (self.spec.k + 1) if self.spec is not None else 1
        bs = self.kv.block_size
        for slot in np.flatnonzero(self.active).tolist():
            if not self.active[slot]:
                continue  # retired/preempted by an earlier row's growth
            target = min(int(self._dev_len[slot]) + look, self.max_len)
            covered = len(self.kv.alloc.owned_by(slot)) * bs
            if target <= covered:
                ok = True
            else:
                # A grow is due: opportunistically take one block of
                # slack so the table (re-uploaded whenever it dirties)
                # dirties half as often — but only when the slack fits
                # without stalling or evicting anyone; under pressure
                # fall back to the exact target.
                slacked = min(target + bs, self.max_len)
                ok = slacked > target and self._extend_both(slot, slacked)
                if not ok:
                    ok = self._grow_row(slot, target)
            if not self.active[slot]:
                continue  # the row itself was evicted to make room
            if ok:
                if self._stalled[slot]:
                    self._stalled[slot] = False
                    self._host_dirty = True
            elif not self._stalled[slot]:
                self._stalled[slot] = True
                self._host_dirty = True
                self.sched_events["stalls"] += 1

    def _grow_row(self, slot: int, target: int) -> bool:
        """True once slot's reservation covers ``target`` tokens (or the
        slot is gone).  On shard exhaustion with preemption enabled:
        drain the ring (pending finishes may free blocks), then evict
        most-blocks victims until the growth fits — the growing row is
        itself a candidate, so progress never deadlocks (submit bounds
        every request's worst case to one shard's capacity)."""
        if self._extend_both(slot, target):
            return True
        if not self.sched.preempt:
            return False
        self._drain_ring()
        while self.slots[slot] is not None:
            if self._extend_both(slot, target):
                return True
            victim = self.sched.pick_victim(self._victim_candidates())
            if victim is None:
                return False
            self._preempt(victim, "pool_dry")
        return True  # the drain retired the row; nothing left to cover

    def _extend_both(self, slot: int, target: int) -> bool:
        """Extend target (and draft, in lockstep) coverage; False when
        either pool's shard is dry.  A target-side extension that the
        draft cannot match is kept — harmless over-reservation the retire
        path frees — and retried whole next call."""
        if self._take_fault("alloc_fail") is not None:
            return False  # injected growth failure: caller stalls/evicts
        added = self.kv.extend(slot, target)
        if added is None:
            return False
        d_added = 0
        if self.spec is not None:
            d_added = self.draft.kv.extend(slot, target)
            if d_added is None:
                return False
        grown = added + d_added
        if grown:
            self.sched_events["grown_blocks"] += grown
            if self.obs.enabled:
                req = self.slots[slot]
                self.obs.on_grow(req.uid if req is not None else -1, slot,
                                 grown, self.kv.alloc.in_use())
        return True

    def _victim_candidates(self):
        """(slot, blocks, class_idx) for every live row (any class)."""
        return [(s, len(self.kv.alloc.owned_by(s)), r.class_idx)
                for s, r in enumerate(self.slots) if r is not None]

    def _preempt(self, slot: int, reason: str) -> None:
        """Evict a live row (callers hold the ring drained): swap its KV
        prefix to host (resume='swap') or fold its generated tokens into
        the prompt (resume='reprefill'), release every block through the
        rollback API, and requeue it at the FRONT of its latency class.
        The freed slot and blocks are immediately reusable."""
        req = self.slots[slot]
        n_ctx = int(self._len_host[slot])
        blocks = len(self.kv.alloc.owned_by(slot))
        if self.obs.enabled:
            # The preempt_ready flag and the actual eviction name the same
            # victim — the observability contract ROADMAP item 1 promised.
            self.obs.on_preempt_ready(req.uid, slot)
        swap_bytes = 0
        if self.sched.resume_mode == "swap":
            req.swap = self._swap_out(slot, n_ctx)
            swap_bytes = req.swap.nbytes
        else:
            # Re-prefill resume: the committed prefix (prompt + generated)
            # becomes the prompt.  Greedy streams are unchanged — the
            # re-prefill reproduces the evicted cache exactly and samples
            # the same next token; temperature streams restart their key
            # chain (use resume='swap' to preserve them).
            fold = req.generated[req.prompt_absorbed:]
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fold, np.int32)])
            req.prompt_absorbed = len(req.generated)
        self.kv.rollback(slot, 0)
        if self.spec is not None:
            self.draft.rollback(slot, 0)
        self.slots[slot] = None
        self.active[slot] = False
        self._stalled[slot] = False
        self._dev_len[slot] = 0
        self._len_host[slot] = 0
        self._host_dirty = True
        self._freed_at[slot] = next(self._free_clock)
        req.slot = None
        req.preemptions += 1
        self.sched.requeue(req)
        self.sched_events["preemptions"] += 1
        self.sched_events["swap_bytes"] += swap_bytes
        if self.obs.enabled:
            self.obs.on_preempt(req.uid, slot, reason, blocks, swap_bytes)

    def _swap_out(self, slot: int, n_ctx: int) -> _SwapPayload:
        """Copy the blocks covering slot's committed context to host (one
        gather per pool leaf + the row's sampling key).  Preemption is off
        the steady-state path, so this D2H is sanctioned — the one-D2H
        step contract is about the decode hot loop.  The payload carries
        a CRC32 so _resume_swap can detect host-side corruption and fall
        back to reprefill instead of scattering garbage KV."""
        n_blocks = self.kv.blocks_for(max(1, n_ctx))
        ids = jnp.asarray(self.kv.alloc.owned_by(slot)[:n_blocks], jnp.int32)
        data = jax.tree.map(
            lambda leaf, ax: np.asarray(
                jax.device_get(jnp.take(leaf, ids, axis=ax))),
            self.kv.pools, self.kv.block_axes)
        key_row = np.asarray(jax.device_get(self.key_data[slot]))
        checksum = _swap_checksum(data)
        req = self.slots[slot]
        if self._take_fault("swap_corrupt",
                            uid=req.uid if req is not None else None):
            # Flip one byte of the first leaf (a private copy —
            # device_get may return read-only views) AFTER checksumming,
            # so the mismatch surfaces at resume time.
            leaves = list(jax.tree.leaves(data))
            bad = np.array(leaves[0], copy=True)
            bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
            leaves[0] = bad
            data = jax.tree.unflatten(jax.tree.structure(data), leaves)
        return _SwapPayload(n_ctx=n_ctx, n_blocks=n_blocks, blocks=data,
                            key_row=key_row, checksum=checksum)

    def _resume_swap(self, req: Request, slot: int) -> None:
        """Re-admit a swap-preempted request by scattering its saved block
        rows into the fresh reservation and restoring the row's device
        state — no recompute, and the PRNG chain continues exactly where
        eviction stopped (temperature streams match an un-preempted run).
        Caller has already reserved admission blocks on ``slot``."""
        pay = req.swap
        if (pay.checksum is not None
                and _swap_checksum(pay.blocks) != pay.checksum):
            # Corrupted swap payload — never scatter it.  Fall back to a
            # reprefill resume over the committed prefix (exact for
            # greedy; temperature restarts the key chain, the documented
            # resume='reprefill' caveat).  The reserved blocks cover the
            # prefix — prompt + generated == n_ctx — so prefill starts
            # immediately on this slot.
            req.swap = None
            fold = req.generated[req.prompt_absorbed:]
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fold, np.int32)])
            req.prompt_absorbed = len(req.generated)
            self.fault_events["swap_fallbacks"] += 1
            self.sched_events["resumes"] += 1
            if self.obs.enabled:
                self.obs.on_resume(req.uid, slot, "reprefill")
            self._prefilling.append(_PrefillTask(req, slot))
            return
        ids = jnp.asarray(self.kv.alloc.owned_by(slot)[:pay.n_blocks],
                          jnp.int32)
        self.kv.pools = jax.tree.map(
            lambda leaf, ax, host: leaf.at[
                (slice(None),) * ax + (ids,)].set(jnp.asarray(host)),
            self.kv.pools, self.kv.block_axes, pay.blocks)
        g = len(req.generated)
        self.cache_len = self.cache_len.at[slot].set(pay.n_ctx)
        self.last_token = self.last_token.at[slot].set(
            int(req.generated[-1]))
        self.budget_dev = self.budget_dev.at[slot].set(
            req.max_new_tokens - g)
        self.key_data = self.key_data.at[slot].set(jnp.asarray(pay.key_row))
        self._active_dev = self._active_dev.at[slot].set(True)
        if self._sh is not None:
            # Eager scatters can drop the roots' expected placements:
            # repin so donated buffers keep aliasing in place.
            if self.kv.shardings is not None:
                self.kv.pools = jax.device_put(self.kv.pools,
                                               self.kv.shardings)
            row, mat = self._sh.row, self._sh.mat
            self.cache_len = jax.device_put(self.cache_len, row)
            self.last_token = jax.device_put(self.last_token, row)
            self.budget_dev = jax.device_put(self.budget_dev, row)
            self.key_data = jax.device_put(self.key_data, mat)
            self._active_dev = jax.device_put(self._active_dev, row)
        self.slots[slot] = req
        self.active[slot] = True
        self._stalled[slot] = False
        req.slot = slot
        self.temps[slot] = req.temperature
        self._eos[slot] = -1 if req.eos_id is None else req.eos_id
        self._len_host[slot] = pay.n_ctx
        self._dev_len[slot] = pay.n_ctx
        self._host_dirty = True
        req.swap = None
        self.sched_events["resumes"] += 1
        if self.obs.enabled:
            self.obs.on_resume(req.uid, slot, "swap")

    # ---- dense: bucketed batched prefill-admission (PR 1 path)

    @staticmethod
    def _make_buckets(bucket_min: int, max_len: int) -> List[int]:
        buckets = []
        b = bucket_min
        while b < max_len:
            buckets.append(b)
            b *= 2
        buckets.append(max_len)
        return buckets

    def _bucket(self, plen: int) -> int:
        for b in self._buckets:
            if plen <= b:
                return b
        return self.max_len

    def _take_group(self, max_r: int) -> List[Request]:
        """Pop up to max_r queued requests sharing the scheduler head's
        prompt-length bucket (FIFO within the bucket and class)."""
        if not self.sched:
            return []
        if not self._bucketed:
            # Recurrent state: exact-length prefill, one request at a time.
            return [self.sched.pop_head()]
        return self.sched.take_bucket(
            max_r, lambda req: self._bucket(len(req.prompt)))

    def _admit_dense(self) -> List[Request]:
        finished: List[Request] = []
        while self.sched:
            free = self._free_slots()
            if not free:
                break
            group = self._take_group(len(free))
            if not group:
                break
            if self._bucketed:
                plen_pad = self._bucket(max(len(r.prompt) for r in group))
                rows = self.max_batch  # fixed shape: compiles per bucket only
            else:
                plen_pad = len(group[0].prompt)
                rows = 1
            tokens = np.zeros((rows, plen_pad), np.int32)
            plens = np.ones((rows,), np.int32)
            slots = np.full((rows,), self.max_batch, np.int32)  # pad = dropped
            budgets = np.zeros((rows,), np.int32)
            rkeys = np.zeros((rows, 2), np.uint32)
            d_keys = (np.zeros((rows, 2), np.uint32)
                      if self.spec is not None else None)
            temps = np.zeros((rows,), np.float32)
            for r, req in enumerate(group):
                tokens[r, : len(req.prompt)] = req.prompt
                plens[r] = len(req.prompt)
                slots[r] = free[r]
                budgets[r] = max(0, req.max_new_tokens - 1)
                temps[r] = req.temperature
                if self.obs.enabled:
                    self.obs.on_admit(req.uid, free[r],
                                      time.perf_counter() - req.t_submit)
            uids = [req.uid for req in group]
            rkeys[: len(group)] = self._request_keys(uids)
            if d_keys is not None:
                d_keys[: len(group)] = self._request_keys(uids, draft=True)
            slots_dev = jnp.asarray(slots)
            (first, self.cache, self.cache_len, self.last_token,
             self.budget_dev, self.key_data, self._active_dev) = self._prefill(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(plens), slots_dev,
                jnp.asarray(budgets), jnp.asarray(rkeys), self.cache_len,
                self.last_token, self.budget_dev, self.key_data,
                jnp.asarray(temps), self._active_dev,
            )
            if self.spec is not None:
                self.draft.cache, self.draft.key_data = self._draft_prefill(
                    self.draft.params, self.draft.cache,
                    jnp.asarray(tokens), slots_dev, self.draft.key_data,
                    jnp.asarray(d_keys),
                )
            toks = np.asarray(jax.device_get(first))
            for r, req in enumerate(group):
                self._finish_or_activate(req, free[r], int(toks[r]), finished)
        return finished

    # --------------------------------------------------------------- decode

    def step(self) -> List[Request]:
        """One pipelined decode step; returns requests finished.

        Dispatches the next decode (or draft+verify) root immediately, then
        consumes the OLDEST in-flight step's token transfer only once the
        ring holds ``pipeline_depth`` entries — so with depth D the device
        runs up to D steps ahead of the host's emission/free bookkeeping.
        Depth 1 reproduces the unpipelined dispatch->sync sequence exactly.
        At most one D2H transfer is consumed per call."""
        if self._draft_dead and self._step_idx >= self._draft_off_until:
            # A killed draft path re-enables after its cool-down; stale
            # draft-cache entries only lower acceptance (verify stays an
            # exact argmax-prefix check), never correctness.  Drain the
            # ring first: plain-decode entries alias last_token, which
            # the verify root DONATES — switching with them in flight
            # would delete an unconsumed token future.
            self._drain_ring()
            self._draft_dead = False
            self.fault_events["draft_reenables"] += 1
            if self.obs.enabled:
                self.obs.on_degraded("draft", False)
        use_spec = self.spec is not None and not self._draft_dead
        if use_spec and self.spec.dynamic_k and self._ring:
            # Per-row window feedback: step N+1's k_row depends on step N's
            # acceptance, so dynamic-k speculation runs the ring at depth 1.
            self._drain_ring()
        if self.paged and self.sched.on_demand:
            # Grow every live row's reservation to cover this dispatch
            # (alloc-only bookkeeping — safe with steps in flight).
            self._ensure_coverage()
        if use_spec:
            self._dispatch_spec()
        else:
            self._dispatch_decode()
        self._step_idx += 1
        if len(self._ring) >= self.pipeline_depth:
            self._consume_one()
        return self._pop_finished()

    def drain(self) -> List[Request]:
        """Consume every in-flight step (one D2H each, oldest first) and
        return all newly finished requests.  The engine calls this before
        any host bookkeeping that must see a synced view — admission,
        defrag, dynamic-k — and callers may use it to flush the tail."""
        self._drain_ring()
        return self._pop_finished()

    def _drain_ring(self) -> None:
        if self.obs.enabled and self._ring:
            self.obs.on_drain(len(self._ring))
        while self._ring:
            self._consume_one()

    def _pop_finished(self) -> List[Request]:
        out, self._pending_finished = self._pending_finished, []
        return out

    # ------------------------------------------------- fault tolerance

    def _take_fault(self, kind: str, uid: Optional[int] = None):
        """Claim a due injected fault of ``kind`` (None without a plan).

        Fires the telemetry fault event for kinds whose injection IS the
        observable fault; poison_logits instead reports at host-side
        detection (see _quarantine), where the fault actually surfaces."""
        if self._faults is None:
            return None
        sp = self._faults.take(kind, self._step_idx, uid=uid)
        if (sp is not None and self.obs.enabled
                and kind != "poison_logits"):
            self.obs.on_fault(kind, -1 if uid is None else uid,
                              self._step_idx)
        return sp

    def _poison_args(self):
        """Trailing poison input for the chaos-variant sampling roots.

        () when the engine was built without poison specs (the roots then
        take no poison argument).  Otherwise the cached device-zero row
        vector — or a freshly-uploaded vector with NaN at each targeted
        live slot when a poison spec fires this dispatch.  Zeros are an
        EXACT identity on the logits (x + 0.0), so healthy rows and
        non-firing steps stay bit-identical to a fault-free engine."""
        if not self._chaos:
            return ()
        vec = None
        mask = self.active & ~self._stalled
        for slot in np.flatnonzero(mask).tolist():
            req = self.slots[slot]
            if req is None:
                continue
            if self._take_fault("poison_logits", uid=req.uid) is None:
                continue
            if vec is None:
                vec = np.zeros((self.max_batch,), np.float32)
            vec[slot] = np.nan
        row = self._sh.row if self._sh is not None else None
        if vec is not None:
            return (jax.device_put(vec, row),)
        if self._poison_zero is None:
            self._poison_zero = jax.device_put(
                np.zeros((self.max_batch,), np.float32), row)
        return (self._poison_zero,)

    def _mark_finished(self, req: Request, reason: str = "stop") -> None:
        """Stamp the terminal reason (first writer wins) and record the
        request — every exit path funnels through here, so finish_reason
        accounting can never miss one."""
        if req.finish_reason is None:
            req.finish_reason = reason
        self.finished_requests[req.uid] = req

    def _abort(self, req: Request, reason: str) -> None:
        """Terminate a request outside the commit paths (shed / cancel /
        shutdown) and surface it via the pending-finished list the next
        public step()/run() iteration returns."""
        self._mark_finished(req, reason)
        self._pending_finished.append(req)
        if self.obs.enabled:
            self._obs_finish(req)

    def _quarantine(self, slot: int, req: Request,
                    finished: List[Request]) -> None:
        """A poisoned row surfaced in the packed D2H word (POISON_TOKEN,
        or spec n_commit == -1): free the slot immediately — healthy rows
        never stall behind it — then either park the request for a
        backoff'd reprefill retry or finish it with
        ``finish_reason='error'``."""
        if self.obs.enabled:
            self.obs.on_fault("poison_logits", req.uid, self._step_idx)
        action, backoff = self._handler.disposition(req)
        self._retire_slot(slot)
        req.slot = None
        if action == "retry":
            # Reprefill-retry from the committed context (the _preempt
            # reprefill arm): generated tokens fold into the prompt, the
            # request parks until its backoff elapses, then requeues at
            # the front of its class.  The poison token was never
            # appended, so the retried context is clean.
            fold = req.generated[req.prompt_absorbed:]
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fold, np.int32)])
            req.prompt_absorbed = len(req.generated)
            self._parked.append((self._step_idx + backoff, req))
            self.fault_events["retried"] += 1
            if self.obs.enabled:
                self.obs.on_retry(req.uid, req.retries, backoff)
        else:
            self.fault_events["quarantined"] += 1
            self._mark_finished(req, "error")
            finished.append(req)
            if self.obs.enabled:
                self._obs_finish(req)

    def _degrade_draft(self) -> None:
        """Draft dispatch failed: run plain decode until the cool-down
        elapses (step() re-enables), flagging the degraded component."""
        self._draft_dead = True
        self._draft_off_until = (
            self._step_idx + self._fault_policy.draft_cooldown_steps)
        self.fault_events["draft_kills"] += 1
        if self.obs.enabled:
            self.obs.on_degraded("draft", True)

    def _unpark(self) -> None:
        """Requeue parked poison-retries whose backoff has elapsed (they
        re-enter at the FRONT of their class, like preemption resumes)."""
        due = [(s, r) for s, r in self._parked if s <= self._step_idx]
        if not due:
            return
        self._parked = [(s, r) for s, r in self._parked
                        if s > self._step_idx]
        for _, req in due:
            self.sched.requeue(req)

    def _shed_expired(self) -> None:
        """Admission-side deadline shedding: drop queued requests whose
        deadline passed before they reached a slot (activated rows run to
        completion — a mid-flight abort would waste the work done)."""
        now = time.monotonic()
        expired = [r for r in self.sched.queued()
                   if r.deadline is not None and r.deadline <= now]
        for req in expired:
            self.sched.remove(req.uid)
            self.fault_events["shed"] += 1
            self._abort(req, "deadline")
            if self.obs.enabled:
                self.obs.on_shed(req.uid, "deadline")

    def _shed_shutdown(self) -> None:
        """Drop every queued + parked request as ``shutdown`` (the drain
        discipline: live rows keep decoding to completion)."""
        for req in list(self.sched.queued()):
            self.sched.remove(req.uid)
            self.fault_events["shed"] += 1
            self._abort(req, "shutdown")
            if self.obs.enabled:
                self.obs.on_shed(req.uid, "shutdown")
        for _, req in self._parked:
            self.fault_events["shed"] += 1
            self._abort(req, "shutdown")
            if self.obs.enabled:
                self.obs.on_shed(req.uid, "shutdown")
        self._parked = []

    def cancel(self, uid: int) -> bool:
        """Cancel a request anywhere in its pre-finish lifecycle.

        Queued and backoff-parked requests are dropped outright; a
        mid-prefill request frees its reservation; a LIVE row drains the
        step ring first (in-flight steps may still write its blocks —
        the same interleave invariant admission holds) and is cancelled
        only if it did not finish during the drain.  Returns True iff
        the request was found and ended with finish_reason='cancelled'."""
        req = self.sched.remove(uid)
        if req is not None:
            self._finish_cancel(req)
            return True
        for i, (_, parked) in enumerate(self._parked):
            if parked.uid == uid:
                del self._parked[i]
                self._finish_cancel(parked)
                return True
        for task in self._prefilling:
            if task.req.uid == uid:
                self._prefilling.remove(task)
                if self.paged:
                    self.kv.free(task.slot)
                    if self.spec is not None:
                        self.draft.free(task.slot)
                    self._freed_at[task.slot] = next(self._free_clock)
                self._finish_cancel(task.req)
                return True
        for slot, live in enumerate(self.slots):
            if live is not None and live.uid == uid:
                self._drain_ring()
                if self.slots[slot] is not live:
                    return False  # finished while the ring drained
                self._retire_slot(slot)
                self._finish_cancel(live)
                return True
        return False

    def _finish_cancel(self, req: Request) -> None:
        self.fault_events["cancelled"] += 1
        self._abort(req, "cancelled")
        if self.obs.enabled:
            self.obs.on_shed(req.uid, "cancelled")

    def request_drain(self) -> None:
        """Signal graceful shutdown (serve.py's SIGTERM handler): run()
        stops admitting and sheds queued/parked work as 'shutdown';
        live rows decode to completion."""
        self._draining = True

    def close(self) -> None:
        """Shut the engine down: drain the ring, then finish EVERYTHING
        still inside (queued, parked, prefilling, live) with
        ``finish_reason='shutdown'``.  Idempotent; subsequent submits
        raise.  Requests that finished normally during the final drain
        keep their 'stop' reason."""
        if self._closed:
            return
        self._draining = True
        self._drain_ring()
        self._shed_shutdown()
        for task in list(self._prefilling):
            if self.paged:
                self.kv.free(task.slot)
                if self.spec is not None:
                    self.draft.free(task.slot)
            self.fault_events["shed"] += 1
            self._abort(task.req, "shutdown")
            if self.obs.enabled:
                self.obs.on_shed(task.req.uid, "shutdown")
        self._prefilling = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self._retire_slot(slot)
            self.fault_events["shed"] += 1
            self._abort(req, "shutdown")
            if self.obs.enabled:
                self.obs.on_shed(req.uid, "shutdown")
        self._closed = True

    def fault_stats(self) -> Dict[str, object]:
        """Fault accounting: every injected fault (by kind, from the
        plan's fired log) plus the engine's degradation counters — the
        block the BENCH stamps and the chaos tests reconcile."""
        injected = self._faults.counts() if self._faults is not None else {}
        out: Dict[str, object] = {
            "injected": injected,
            "injected_total": int(sum(injected.values())),
            "parked": len(self._parked),
            "degraded": self.degraded_components(),
        }
        out.update(self.fault_events)
        return out

    def degraded_components(self) -> Dict[str, object]:
        """Currently-degraded components, empty when fully healthy (the
        /healthz provider: non-empty answers 503)."""
        out: Dict[str, object] = {}
        if self.spec is not None and self._draft_dead:
            out["draft"] = {"off_until_step": self._draft_off_until}
        stalled = np.flatnonzero(self._stalled).tolist()
        if stalled:
            out["stalled_slots"] = [int(s) for s in stalled]
        if self._draining:
            out["draining"] = True
        return out

    def engine_snapshot(self) -> Dict[str, object]:
        """JSON-serializable engine state for ServingFault post-mortems
        (and the chaos CLI's fault report)."""
        return {
            "step": self._step_idx,
            "ring_depth": len(self._ring),
            "pipeline_depth": self.pipeline_depth,
            "slots": [
                None if r is None else {
                    "uid": r.uid,
                    "generated": len(r.generated),
                    "len": int(self._len_host[s]),
                    "stalled": bool(self._stalled[s]),
                }
                for s, r in enumerate(self.slots)],
            "queued": len(self.sched),
            "parked": len(self._parked),
            "prefilling": len(self._prefilling),
            "pool_free_blocks": (self.kv.alloc.free_blocks()
                                 if self.paged else None),
            "degraded": self.degraded_components(),
            "faults": self.fault_stats(),
        }

    def _host_inputs(self):
        """Device-resident (host_keep, temps, eos[, k_row]) for dispatch,
        rebuilt only when admission/finish bookkeeping dirtied them."""
        if self._host_dirty:
            # Explicit device_put (guard-sanctioned; sharded when meshed).
            # Stalled rows are live but must not advance: host_keep drops
            # them, so the device freezes their entire per-slot state (the
            # same mechanism that freezes finished rows) until growth
            # succeeds and un-stalls them.
            row = self._sh.row if self._sh is not None else None
            keep = self.active & ~self._stalled
            self._keep_dev = jax.device_put(keep, row)
            self._temps_dev = jax.device_put(self.temps, row)
            self._eos_dev = jax.device_put(self._eos, row)
            if self.spec is not None:
                self._k_row_dev = jax.device_put(self._k_row, row)
            if self.paged:
                # Dispatch-order permutation (longest rows first per DP
                # shard).  Any fixed permutation is token-stream neutral —
                # the root un-permutes its logits — so reusing it between
                # dirty events is correct even as lengths advance.
                order = self.sched.row_order(self._dev_len, keep,
                                             self.max_batch, self.dp_shards)
                if order is None:
                    order = np.arange(self.max_batch, dtype=np.int32)
                self._order_dev = jax.device_put(order, row)
            self._host_dirty = False
        return self._keep_dev, self._temps_dev, self._eos_dev

    def _dispatch_decode(self) -> None:
        """Launch one decode root and ring its token future (no sync)."""
        t0 = time.perf_counter()
        mask = self.active & ~self._stalled
        with self._guard(), self.obs.span("serving.dispatch.decode"):
            host_keep, temps, eos = self._host_inputs()
            if self.paged:
                (sampled, self.kv.pools, self.cache_len, self.budget_dev,
                 self.key_data, self._active_dev) = self._decode(
                    self.params, self.kv.pools, self.kv.table_device(),
                    self.last_token, self.cache_len, self.budget_dev,
                    self.key_data, self._active_dev, host_keep, temps, eos,
                    self._order_dev, *self._poison_args(),
                )
            else:
                (sampled, self.cache, self.cache_len, self.budget_dev,
                 self.key_data, self._active_dev) = self._decode(
                    self.params, self.cache, self.last_token, self.cache_len,
                    self.budget_dev, self.key_data, self._active_dev,
                    host_keep, temps, eos, *self._poison_args(),
                )
        self.last_token = sampled
        if self.paged:
            self._dev_len += mask  # each dispatched row writes one entry
        self._note_occupancy(mask)
        self._ring.append(_InFlight(sampled, mask,
                                    time.perf_counter() - t0))
        if self.obs.enabled:
            self._obs_dispatch("decode", mask)

    def _dispatch_spec(self) -> None:
        """Launch one speculative step (fused draft-K root + chunk-verify
        root) and ring its packed committed-token future (no sync)."""
        t0 = time.perf_counter()
        mask = self.active & ~self._stalled
        with self._guard():
            host_keep, temps, eos = self._host_inputs()
            k_row = self._k_row_dev

            try:
                with self.obs.span("serving.dispatch.spec_draft"):
                    if self._take_fault("draft_kill") is not None:
                        # Raised BEFORE the root call, so no draft buffer
                        # has been donated — engine state is untouched.
                        raise RuntimeError("injected draft dispatch kill")
                    (proposals, q_probs, self.draft.pools,
                     self.draft.key_data) = self._spec_draft(
                        self.draft.params, self.draft.pools,
                        self.draft.table_device(),
                        self.last_token, self.cache_len, self.draft.key_data,
                        self._active_dev, host_keep, temps,
                    )
            except Exception:
                # Draft path died: degrade to plain decode (greedy streams
                # are token-identical — verify was always an exact argmax
                # prefix check) and re-enable after the cool-down.
                self._degrade_draft()
                self._dispatch_decode()
                return
            target_cache = self.kv.pools if self.paged else self.cache
            bt = self.kv.table_device() if self.paged else None
            with self.obs.span("serving.dispatch.spec_verify"):
                (pack, target_cache, self.cache_len, self.last_token,
                 self.budget_dev, self.key_data,
                 self._active_dev) = self._spec_verify(
                    self.params, target_cache, bt, self.last_token, proposals,
                    q_probs, self.cache_len, self.budget_dev, self.key_data,
                    self._active_dev, host_keep, temps, eos, k_row,
                    *self._poison_args(),
                )
        if self.paged:
            self.kv.pools = target_cache
        else:
            self.cache = target_cache
        if self.paged:
            # Conservative device-length advance: verify may write the full
            # k+1 proposal entries before rolling back to the accepted
            # prefix; _commit_spec reconciles once acceptance is known.
            self._dev_len += (self.spec.k + 1) * mask
        self._note_occupancy(mask)
        self._ring.append(_InFlight(pack, mask, time.perf_counter() - t0,
                                    spec=True, k_row=self._k_row.copy()))
        if self.obs.enabled:
            self._obs_dispatch("spec", mask)

    def _note_occupancy(self, mask: np.ndarray) -> None:
        """Accumulate per-dispatch occupancy: live committed tokens over
        reserved pool tokens (the on-demand payoff metric — worst-case
        admission reserves far more than it has committed) and live rows
        per step (mean batch occupancy).  Host ints only."""
        self._occ_rows_sum += int(mask.sum())
        self._occ_rows_steps += 1
        if not self.paged:
            return
        reserved = self.kv.alloc.in_use() * self.kv.block_size
        if reserved > 0:
            live = int(self._len_host[mask].sum())
            self._occ_live_frac_sum += live / reserved
            self._occ_samples += 1

    def _obs_dispatch(self, kind: str, mask: np.ndarray) -> None:
        """Step-dispatch telemetry: ring depth, live rows, per-shard pool
        occupancy — all host ints the engine already tracks."""
        pool = peaks = None
        live_tok = reserved_tok = None
        if self.paged:
            alloc = self.kv.alloc
            pool = [alloc.in_use(s) for s in range(alloc.num_shards)]
            peaks = self.kv.blocks_per_shard
            reserved_tok = alloc.in_use() * self.kv.block_size
            live_tok = int(self._len_host[mask].sum())
        self.obs.on_step_dispatch(kind, len(self._ring), int(mask.sum()),
                                  self._ring[-1].dispatch_s, pool, peaks,
                                  live_tok, reserved_tok)

    def _consume_one(self) -> None:
        """Sync the oldest in-flight step's tokens (the ONE D2H this step
        ever costs) and run its emission/finish/free bookkeeping, appending
        newly finished requests to the pending list."""
        entry = self._ring.popleft()
        sp = self._take_fault("straggler")
        if sp is not None:
            time.sleep(sp.delay_s)  # simulated hung transfer
        t0 = time.perf_counter()
        with self.obs.span("serving.ring_sync"):
            toks = np.asarray(jax.device_get(entry.tokens))
        t_sync = time.perf_counter() - t0
        if sp is not None:
            t_sync += sp.delay_s  # the sleep IS the stall being modeled
        self.decode_transfers += 1
        dur = entry.dispatch_s + t_sync
        if self._watchdog is not None:
            verdict = self._watchdog.observe(dur)
            if verdict != "ok":
                self.fault_events["straggler_slow"] += 1
                if verdict == "trip":
                    self.fault_events["straggler_trips"] += 1
                if self.obs.enabled:
                    self.obs.on_straggler(verdict, dur)
        timeout = self._fault_policy.step_timeout_s
        if timeout is not None and dur > timeout:
            raise ServingFault(
                f"engine step exceeded hard timeout: {dur:.3f}s > "
                f"{timeout}s (dispatch {entry.dispatch_s:.3f}s + sync "
                f"{t_sync:.3f}s)", kind="step_timeout",
                step=self._step_idx, snapshot=self.engine_snapshot())
        if entry.spec:
            finished = self._commit_spec(entry, toks)
        else:
            finished = self._commit_decode(entry, toks)
        self._pending_finished.extend(finished)
        t_host = time.perf_counter() - t0 - t_sync
        self.step_device_wait_s.append(t_sync)
        self.step_host_s.append(t_host)
        self.step_times.append(entry.dispatch_s + t_sync + t_host)
        if self.obs.enabled:
            self.obs.on_step_consume("spec" if entry.spec else "decode",
                                     t_sync, t_host)

    def _commit_decode(self, entry: _InFlight,
                       toks: np.ndarray) -> List[Request]:
        # A slot live in entry.mask whose request has since been retired
        # (it finished in an OLDER ring entry) carries a garbage token the
        # device either masked or wrote into the slot's still-reserved
        # space: skip it.  FIFO consumption guarantees the converse — a
        # row still live here was device-active at this entry's dispatch.
        live = np.fromiter((r is not None for r in self.slots), bool,
                           self.max_batch)
        adv = entry.mask & live
        self._len_host += adv
        finished: List[Request] = []
        now = time.perf_counter() if self.obs.enabled else 0.0
        for slot, req in enumerate(self.slots):
            if req is None or not adv[slot]:
                continue
            tok = int(toks[slot])
            if tok == POISON_TOKEN:
                # Device-side finite check tripped (NaN/Inf logits): the
                # packed D2H word carries the verdict, so detection costs
                # no extra transfer.  The sentinel is never emitted.
                self._quarantine(slot, req, finished)
                continue
            req.generated.append(tok)
            if self.obs.enabled:
                req.t_last = now
                self.obs.on_commit(req.uid, slot, 1)
            if (req.done or self._len_host[slot] >= self.max_len - 1
                    or tok == self._eos[slot]):
                finished.append(req)
                self._mark_finished(req)
                self._retire_slot(slot)
                if self.obs.enabled:
                    self._obs_finish(req)
        return finished

    def _commit_spec(self, entry: _InFlight,
                     toks: np.ndarray) -> List[Request]:
        k = self.spec.k
        toks_mat = toks[:, : k + 1]
        n_commit, m_acc = toks[:, k + 1], toks[:, k + 2]
        finished: List[Request] = []
        now = time.perf_counter() if self.obs.enabled else 0.0
        for slot, req in enumerate(self.slots):
            if req is None or not entry.mask[slot]:
                continue
            if int(n_commit[slot]) < 0:
                # Verify-side finite check: n_commit == -1 flags NaN/Inf
                # logits for this row (its budget was NOT charged) —
                # quarantine before any speculative accounting.
                self._quarantine(slot, req, finished)
                continue
            m = int(m_acc[slot])
            k_eff = int(entry.k_row[slot])
            req.spec_proposed += k_eff
            req.spec_accepted += m
            self.spec_proposed += k_eff
            self.spec_accepted += m
            self.spec_step_rows += 1
            if self.obs.enabled:
                self.obs.on_spec_row(k_eff, m)
            self._len_host[slot] += m + 1  # entries committed to cache
            if self.paged:
                # Dispatch advanced _dev_len by the conservative k+1;
                # the cache actually kept m+1 — reconcile the difference
                # so coverage targets track the committed length.
                self._dev_len[slot] -= k - m
            if self.spec.dynamic_k:
                if m == k_eff:
                    self._k_row[slot] = min(k, k_eff + 1)
                elif m == 0:
                    self._k_row[slot] = max(1, k_eff - 1)
                self._host_dirty = True
            done = False
            appended = 0
            base_len = self._len_host[slot] - (m + 1)
            for j in range(int(n_commit[slot])):
                tok = int(toks_mat[slot, j])
                req.generated.append(tok)
                self.spec_committed += 1
                appended += 1
                # Sequential-decode finish semantics: cached length after
                # this token is base_len + j + 1.
                if (req.done or base_len + j + 1 >= self.max_len - 1
                        or tok == self._eos[slot]):
                    done = True
                    break
            if self.obs.enabled and appended:
                req.t_last = now
                self.obs.on_commit(req.uid, slot, appended)
            if done:
                finished.append(req)
                self._mark_finished(req)
                self._retire_slot(slot)
                if self.obs.enabled:
                    self._obs_finish(req)
        return finished

    # ------------------------------------------------------------ telemetry

    def stats(self) -> Dict[str, float]:
        """Decode-step timing summary (seconds) + throughput proxy.

        ``device_wait_*`` is the D2H sync stall per consumed step and
        ``host_*`` the emission/free bookkeeping that follows — the two
        halves the pipeline overlaps with the device's next step."""
        if not self.step_times:
            # Fully-keyed zero snapshot: callers (serve.py, benchmarks,
            # dashboards) index timing keys unconditionally — an engine
            # that never stepped must not crash them or emit NaN.
            return {
                "steps": 0,
                "step_mean_s": 0.0, "step_p50_s": 0.0,
                "step_p90_s": 0.0, "step_p99_s": 0.0,
                "device_wait_mean_s": 0.0, "device_wait_p50_s": 0.0,
                "host_mean_s": 0.0, "host_p50_s": 0.0,
                "pipeline_depth": self.pipeline_depth,
                "live_rows": int(self.active.sum()),
            }
        ts = np.asarray(self.step_times)
        dw = np.asarray(self.step_device_wait_s)
        hb = np.asarray(self.step_host_s)
        n_live = max(1, int(self.active.sum()))
        return {
            "steps": len(ts),
            "step_mean_s": float(ts.mean()),
            "step_p50_s": float(np.percentile(ts, 50)),
            "step_p90_s": float(np.percentile(ts, 90)),
            "step_p99_s": float(np.percentile(ts, 99)),
            "device_wait_mean_s": float(dw.mean()),
            "device_wait_p50_s": float(np.percentile(dw, 50)),
            "host_mean_s": float(hb.mean()),
            "host_p50_s": float(np.percentile(hb, 50)),
            "pipeline_depth": self.pipeline_depth,
            "live_rows": n_live,
        }

    def spec_stats(self) -> Dict[str, float]:
        """Speculative-decoding accounting: acceptance rate and committed
        tokens per live row-step (>= 1.0; the speedup proxy)."""
        if self.spec is None:
            return {}
        return {
            "k": self.spec.k,
            "dynamic_k": bool(self.spec.dynamic_k),
            "proposed": self.spec_proposed,
            "accepted": self.spec_accepted,
            "committed": self.spec_committed,
            "acceptance_rate": self.spec_accepted / max(1, self.spec_proposed),
            "committed_per_row_step":
                self.spec_committed / max(1, self.spec_step_rows),
            "draft_hbm_bytes": self.draft.hbm_bytes(),
        }

    def scheduler_stats(self) -> Dict[str, object]:
        """Scheduling policy + lifecycle accounting: admission policy,
        preempt/resume/grow counters, and the occupancy means the
        overcommit benchmark reports (live committed tokens / reserved
        pool tokens per dispatch; live rows per step)."""
        occ = (self._occ_live_frac_sum / self._occ_samples
               if self._occ_samples else None)
        rows = (self._occ_rows_sum / self._occ_rows_steps
                if self._occ_rows_steps else 0.0)
        return {
            "admission_policy": self.sched.cfg.admission,
            "preempt_enabled": self.sched.preempt,
            "resume_mode": self.sched.resume_mode,
            "priority_classes": list(self.sched.cfg.priority_classes),
            "preempt_count": self.sched_events["preemptions"],
            "swap_bytes": self.sched_events["swap_bytes"],
            "grown_blocks": self.sched_events["grown_blocks"],
            "resumes": self.sched_events["resumes"],
            "stalls": self.sched_events["stalls"],
            "occupancy_live_frac": occ,
            "mean_live_rows": rows,
            "queued": len(self.sched),
        }

    def mesh_shape(self) -> Dict[str, int]:
        """The serving mesh as {dp, tp, devices} ((1, 1, 1) when meshless
        — the layout every sharded stat reduces to on one device)."""
        if self.par is None:
            return {"dp": 1, "tp": 1, "devices": 1}
        m = self.par.mesh
        dp = int(np.prod([m.shape[a] for a in self.par.dp_axes]))
        tp = int(m.shape[self.par.tp_axis]) if self.par.tp_axis else 1
        return {"dp": dp, "tp": tp, "devices": int(m.size)}

    def cache_stats(self) -> Dict[str, float]:
        """Cache memory accounting: HBM bytes (global + per device) +
        live/reserved tokens."""
        live = int((self._len_host * self.active).sum())
        if self.paged:
            s = dict(self.kv.stats(), layout="paged")
        else:
            slab = int(sum(
                leaf.nbytes for leaf in jax.tree.leaves(self.cache)
            ))
            s = {
                "layout": "dense",
                "tokens_capacity": self.max_batch * self.max_len,
                "cache_hbm_bytes": slab,
                "dp_shards": self.dp_shards,
                # The slab shards over its batch dim: each device holds
                # max_batch / dp rows (the whole slab when unsharded).
                "per_device_cache_hbm_bytes": slab // self.dp_shards,
            }
        s["mesh"] = self.mesh_shape()
        s["live_tokens"] = live
        if self.spec is not None:
            s["draft_hbm_bytes"] = self.draft.hbm_bytes()
        return s

    def defrag(self) -> int:
        """Compact live blocks to the lowest pool ids (paged only).
        Returns the number of blocks moved (target + draft pools).

        Drains the step pipeline first: the move map comes from the host
        allocator, which must have consumed every in-flight step's frees
        before permuting the pools (finishes surface from the next public
        step()/_admit()/drain())."""
        if not self.paged:
            return 0
        self._drain_ring()
        moved = len(self.kv.defrag())
        if self.spec is not None:
            moved += len(self.draft.kv.defrag())
        if self.obs.enabled:
            self.obs.on_defrag(moved)
        return moved

    def telemetry_snapshot(self) -> Dict:
        """Full observability snapshot (metrics + trace tail + engine
        stats) — ``{}`` when the engine runs without telemetry."""
        return self.obs.snapshot(self) if self.obs.enabled else {}
