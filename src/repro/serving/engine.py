"""Batched, host-sync-free serving engine (continuous batching) over
(compressed) weights.

Slot-based: a fixed (max_batch, max_len) cache; requests are admitted into
free slots, every engine step decodes one token for all live rows, finished
rows free their slots immediately — new requests join mid-flight without
stalling the running batch.

Hot-path design (the paper's Eq. 6 payoff is only real if the engine keeps
up with the factored matmuls):

  * ALL per-slot state lives on device: cache, cache_len, last_token and a
    per-slot PRNG key array.  The host mirrors only what it needs for
    scheduling (active flags, lengths) and those mirrors are updated from
    host-side bookkeeping, never by reading device buffers.
  * ``step()`` is ONE jitted call (decode + batched greedy/temperature
    sampling for every live row) followed by ONE device->host transfer of
    the sampled token vector.  No per-slot ``int(...)`` syncs.
  * Prefill compiles once per prompt-length BUCKET (powers of two), not
    once per prompt length: prompts are right-padded to the bucket, the
    causal mask keeps real positions exact, and the padded cache tail is
    masked by cache_len until decode overwrites it.  Pad-sensitive models
    — recurrent cache state (SSM/RWKV) and token-choice MoE (padding
    tokens would compete for expert-capacity slots) — fall back to
    exact-length prefill (detected via ``prefill_pad_safe``).
  * Admission is batched: up to ``max_batch`` queued requests sharing a
    bucket are prefilled in one call and scattered into their slots with
    one multi-row cache write (padding rows carry an out-of-range slot
    index, so their writes drop).

Decode-time nested-lowrank matmuls of compressed dense/attention/MLP
layers route through ``kernels/nested_lowrank/ops.py`` (fused Pallas
kernel on TPU, jnp oracle on CPU) via ``linear_apply``'s default
dispatch; MoE expert matmuls keep their own stacked-einsum twin
(``moe._expert_ffn``) and are not kernel-routed yet.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_sample_step, make_prefill_admit_step
from repro.models.api import Model, prefill_pad_safe


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        max_batch: int = 8,
        max_len: int = 512,
        seed: int = 0,
        bucket_min: int = 16,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len

        # Device-resident state (never read back except the sampled tokens).
        self.cache = model.init_cache(max_batch, max_len)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.key_data = jax.random.key_data(
            jax.random.split(jax.random.key(seed), max_batch)
        )

        # Host mirrors for scheduling (updated by bookkeeping, not syncs).
        self.active = np.zeros((max_batch,), bool)
        self.temps = np.zeros((max_batch,), np.float32)
        self._len_host = np.zeros((max_batch,), np.int64)

        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._uid = itertools.count()

        self._decode = jax.jit(make_decode_sample_step(model))
        self._prefill = jax.jit(make_prefill_admit_step(model, max_len))
        self._bucketed = prefill_pad_safe(model)
        self._buckets = self._make_buckets(bucket_min, max_len)

        # Telemetry: step() wall times (includes the one D2H sync).
        self.step_times: List[float] = []
        self.decode_transfers = 0

    # --------------------------------------------------------------- API

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               temperature: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds max_len-1={self.max_len - 1}"
            )
        req = Request(next(self._uid), prompt, max_new_tokens, temperature)
        self.queue.append(req)
        return req.uid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue + slots drain.  Returns uid -> generated."""
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            for req in self._admit():
                finished[req.uid] = req.generated
            if not self.active.any():
                if not self.queue:
                    break
                continue
            for req in self.step():
                finished[req.uid] = req.generated
        return finished

    # ------------------------------------------------------------- admission

    @staticmethod
    def _make_buckets(bucket_min: int, max_len: int) -> List[int]:
        buckets = []
        b = bucket_min
        while b < max_len:
            buckets.append(b)
            b *= 2
        buckets.append(max_len)
        return buckets

    def _bucket(self, plen: int) -> int:
        for b in self._buckets:
            if plen <= b:
                return b
        return self.max_len

    def _take_group(self, max_r: int) -> List[Request]:
        """Pop up to max_r queued requests sharing the front request's
        prompt-length bucket (FIFO within the bucket)."""
        if not self.queue:
            return []
        if not self._bucketed:
            # Recurrent state: exact-length prefill, one request at a time.
            return [self.queue.popleft()]
        want = self._bucket(len(self.queue[0].prompt))
        group, rest = [], deque()
        while self.queue:
            req = self.queue.popleft()
            if len(group) < max_r and self._bucket(len(req.prompt)) == want:
                group.append(req)
            else:
                rest.append(req)
        self.queue = rest
        return group

    def _admit(self) -> List[Request]:
        """Admit queued requests into free slots (batched per bucket).
        Returns requests that finished at admission (max_new_tokens <= 1)."""
        finished: List[Request] = []
        while self.queue:
            free = [i for i in range(self.max_batch) if not self.active[i]]
            if not free:
                break
            group = self._take_group(len(free))
            if not group:
                break
            if self._bucketed:
                plen_pad = self._bucket(max(len(r.prompt) for r in group))
                rows = self.max_batch  # fixed shape: compiles per bucket only
            else:
                plen_pad = len(group[0].prompt)
                rows = 1
            tokens = np.zeros((rows, plen_pad), np.int32)
            plens = np.ones((rows,), np.int32)
            slots = np.full((rows,), self.max_batch, np.int32)  # pad = dropped
            temps = np.zeros((rows,), np.float32)
            for r, req in enumerate(group):
                tokens[r, : len(req.prompt)] = req.prompt
                plens[r] = len(req.prompt)
                slots[r] = free[r]
                temps[r] = req.temperature
            first, self.cache, self.cache_len, self.last_token, self.key_data = (
                self._prefill(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(plens), jnp.asarray(slots), self.cache_len,
                    self.last_token, self.key_data, jnp.asarray(temps),
                )
            )
            toks = np.asarray(jax.device_get(first))
            for r, req in enumerate(group):
                slot = free[r]
                req.slot = slot
                req.generated.append(int(toks[r]))
                self.temps[slot] = req.temperature
                self._len_host[slot] = len(req.prompt)
                if req.done or self._len_host[slot] >= self.max_len - 1:
                    finished.append(req)
                else:
                    self.slots[slot] = req
                    self.active[slot] = True
        return finished

    # --------------------------------------------------------------- decode

    def step(self) -> List[Request]:
        """One decode step for all live rows; returns requests finished.

        Exactly one device->host transfer: the sampled token vector."""
        t0 = time.perf_counter()
        active = self.active.copy()
        sampled, self.cache, self.cache_len, self.key_data = self._decode(
            self.params, self.cache, self.last_token, self.cache_len,
            self.key_data, jnp.asarray(active), jnp.asarray(self.temps),
        )
        self.last_token = sampled
        self._len_host += active
        toks = np.asarray(jax.device_get(sampled))  # the step's single D2H
        self.decode_transfers += 1
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None or not active[slot]:
                continue
            req.generated.append(int(toks[slot]))
            if req.done or self._len_host[slot] >= self.max_len - 1:
                finished.append(req)
                self.slots[slot] = None
                self.active[slot] = False
        self.step_times.append(time.perf_counter() - t0)
        return finished

    # ------------------------------------------------------------ telemetry

    def stats(self) -> Dict[str, float]:
        """Decode-step timing summary (seconds) + throughput proxy."""
        if not self.step_times:
            return {"steps": 0}
        ts = np.asarray(self.step_times)
        n_live = max(1, int(self.active.sum()))
        return {
            "steps": len(ts),
            "step_mean_s": float(ts.mean()),
            "step_p50_s": float(np.percentile(ts, 50)),
            "step_p90_s": float(np.percentile(ts, 90)),
            "step_p99_s": float(np.percentile(ts, 99)),
            "live_rows": n_live,
        }
