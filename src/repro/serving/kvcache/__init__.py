"""Paged KV-cache subsystem: block-table allocator + device block pools.

Host bookkeeping (BlockAllocator) is authoritative; PagedKVCache mirrors it
onto the device as a block pool pytree plus a per-step block-table upload.
See serving/engine.py for how the pieces are driven."""

from .allocator import BlockAllocator
from .paged import PagedKVCache

__all__ = ["BlockAllocator", "PagedKVCache"]
