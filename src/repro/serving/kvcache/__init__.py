"""Paged KV-cache subsystem: block-table allocator + device block pools.

Host bookkeeping (BlockAllocator) is authoritative; PagedKVCache mirrors it
onto the device as a block pool pytree plus a per-step block-table upload.
Under a DP x TP serving mesh the pools shard over their block dim and the
block id space partitions into per-DP-shard ranges, with the allocator
authoritative per shard (its own free list, backpressure, and peak).
See serving/engine.py for how the pieces are driven."""

from .allocator import BlockAllocator
from .paged import PagedKVCache, resolve_num_blocks

__all__ = ["BlockAllocator", "PagedKVCache", "resolve_num_blocks"]
