"""Host-side block allocator for the paged KV cache.

Pure bookkeeping — no device arrays.  Physical blocks are integer ids into
the device block pool; the allocator hands contiguous-in-ID-order *lists*
(not contiguous memory — the block table absorbs any fragmentation) to
owners (engine slots) and reclaims them when a request finishes.

Sharding: when the device pools are sharded over their block dim across a
DP mesh axis (``num_shards > 1``), block id space is partitioned into
``num_shards`` contiguous ranges — block ``b`` lives on device shard
``b // blocks_per_shard`` — and every allocation is pinned to one shard so
a slot's reads/writes stay device-local.  The allocator stays fully
host-authoritative per shard: each shard has its own free list, its own
backpressure, and its own peak, with ``num_shards == 1`` reproducing the
unsharded behavior exactly.

``defrag()`` compacts live blocks into the lowest ids OF THEIR SHARD RANGE
and returns the move map; moves never cross shards, so the engine's device
permutation is block-diagonal over the mesh (no cross-device traffic).
With block tables, compaction is never needed for correctness — it exists
so a pool can be shrunk (or a snapshot taken) from a per-shard prefix."""

from __future__ import annotations

from typing import Dict, Hashable, List, Optional


class BlockAllocator:
    def __init__(self, num_blocks: int, num_shards: int = 1):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        if num_shards <= 0:
            raise ValueError(f"num_shards must be positive, got {num_shards}")
        if num_blocks % num_shards != 0:
            raise ValueError(
                f"num_blocks={num_blocks} not divisible by "
                f"num_shards={num_shards}"
            )
        self.num_blocks = num_blocks
        self.num_shards = num_shards
        self.blocks_per_shard = num_blocks // num_shards
        # Ascending free list per shard; allocation pops the lowest ids
        # first, which keeps live blocks clustered and defrag moves small.
        self._free: List[List[int]] = [
            list(range(s * self.blocks_per_shard,
                       (s + 1) * self.blocks_per_shard))
            for s in range(num_shards)
        ]
        self._owned: Dict[Hashable, List[int]] = {}
        # Peak accounting: aggregate (all shards, the historical metric) AND
        # per shard — per-DEVICE HBM truthfulness when pools are sharded.
        self.peak_in_use = 0
        self.peak_by_shard: List[int] = [0] * num_shards
        # Lifetime event counters (plain ints, no deps): scraped by the
        # observability layer (repro.obs) and snapshotted by the engine —
        # bookkeeping only, never consulted for allocation decisions.
        self.counters: Dict[str, int] = {
            "alloc_calls": 0, "alloc_denied": 0, "alloc_blocks": 0,
            "grow_calls": 0, "grow_denied": 0, "grown_blocks": 0,
            "free_calls": 0, "freed_blocks": 0,
            "release_suffix_calls": 0, "defrag_calls": 0,
            "defrag_moved_blocks": 0,
        }

    # ------------------------------------------------------------ queries

    def home_shard(self, block: int) -> int:
        return block // self.blocks_per_shard

    def in_use(self, shard: Optional[int] = None) -> int:
        if shard is None:
            return self.num_blocks - sum(len(f) for f in self._free)
        return self.blocks_per_shard - len(self._free[shard])

    def free_blocks(self, shard: Optional[int] = None) -> int:
        if shard is None:
            return sum(len(f) for f in self._free)
        return len(self._free[shard])

    def owned_by(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def can_alloc(self, n: int, shard: int = 0) -> bool:
        return n <= len(self._free[shard])

    # ------------------------------------------------------------ mutation

    def _note_peaks(self) -> None:
        self.peak_in_use = max(self.peak_in_use, self.in_use())
        for s in range(self.num_shards):
            self.peak_by_shard[s] = max(self.peak_by_shard[s], self.in_use(s))

    def alloc(self, owner: Hashable, n: int, shard: int = 0) -> Optional[List[int]]:
        """Allocate n blocks for owner from one shard's range (appending to
        any it already holds).  Returns the new block ids, or None (and no
        state change) when the shard cannot satisfy the request — admission
        backpressure is per shard."""
        if n < 0:
            raise ValueError(f"negative block count {n}")
        self.counters["alloc_calls"] += 1
        free = self._free[shard]
        if n > len(free):
            self.counters["alloc_denied"] += 1
            return None
        ids = free[:n]
        del free[:n]
        self.counters["alloc_blocks"] += n
        self._owned.setdefault(owner, []).extend(ids)
        self._note_peaks()
        return ids

    def grow(self, owner: Hashable, n: int, shard: int = 0) -> Optional[List[int]]:
        """Extend an EXISTING owner's reservation by n blocks from its home
        shard — the allocate-on-demand path: admission reserves the prompt,
        and decode grows the suffix one block boundary at a time.  Returns
        the appended ids, or None (no state change) when the shard is dry
        (caller stalls the row or preempts a victim).  Distinct counters
        from ``alloc`` so occupancy telemetry can split admission
        reservations from on-demand growth."""
        if n < 0:
            raise ValueError(f"negative block count {n}")
        if owner not in self._owned:
            raise KeyError(f"grow for unknown owner {owner!r}")
        self.counters["grow_calls"] += 1
        free = self._free[shard]
        if n > len(free):
            self.counters["grow_denied"] += 1
            return None
        ids = free[:n]
        del free[:n]
        self.counters["grown_blocks"] += n
        self._owned[owner].extend(ids)
        self._note_peaks()
        return ids

    def free(self, owner: Hashable) -> List[int]:
        """Release all blocks held by owner to their home shards (no-op for
        unknown owners)."""
        ids = self._owned.pop(owner, [])
        if ids:
            self.counters["free_calls"] += 1
            self.counters["freed_blocks"] += len(ids)
        self._return(ids)
        return ids

    def _return(self, ids: List[int]) -> None:
        if not ids:
            return
        touched = set()
        for b in ids:
            s = self.home_shard(b)
            self._free[s].append(b)
            touched.add(s)
        for s in touched:
            self._free[s].sort()

    def release_suffix(self, owner: Hashable, n_keep: int) -> List[int]:
        """Shrink an owner to its FIRST n_keep blocks, returning the freed
        suffix.  The block table maps logical positions to blocks in owned
        order, so a per-row length rollback frees exactly this suffix —
        the allocator half of the cache-rollback API."""
        if n_keep < 0:
            raise ValueError(f"negative n_keep {n_keep}")
        ids = self._owned.get(owner, [])
        freed = ids[n_keep:]
        if freed:
            self.counters["release_suffix_calls"] += 1
            self.counters["freed_blocks"] += len(freed)
            self._owned[owner] = ids[:n_keep]
            if not self._owned[owner]:
                del self._owned[owner]
            self._return(freed)
        return freed

    def defrag(self) -> Dict[int, int]:
        """Compact live blocks into the lowest ids of their shard range:
        returns {old: new} for every moved block and rewrites the per-owner
        lists in place.  Shard-local by construction (one shard == the
        historical whole-pool compaction)."""
        moves: Dict[int, int] = {}
        live_all = sorted(b for ids in self._owned.values() for b in ids)
        for s in range(self.num_shards):
            base = s * self.blocks_per_shard
            live = [b for b in live_all if self.home_shard(b) == s]
            moves.update({old: base + new for new, old in enumerate(live)
                          if old != base + new})
            self._free[s] = list(range(base + len(live),
                                       base + self.blocks_per_shard))
        if moves:
            for ids in self._owned.values():
                ids[:] = [moves.get(b, b) for b in ids]
        self.counters["defrag_calls"] += 1
        self.counters["defrag_moved_blocks"] += len(moves)
        return moves
