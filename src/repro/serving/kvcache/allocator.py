"""Host-side block allocator for the paged KV cache.

Pure bookkeeping — no device arrays.  Physical blocks are integer ids into
the device block pool; the allocator hands contiguous-in-ID-order *lists*
(not contiguous memory — the block table absorbs any fragmentation) to
owners (engine slots) and reclaims them when a request finishes.

``defrag()`` compacts live blocks into the lowest ids and returns the move
map; the engine applies the same permutation to the device pools and block
table.  With block tables, compaction is never needed for correctness —
it exists so a pool can be shrunk (or a snapshot taken) from a prefix."""

from __future__ import annotations

from typing import Dict, Hashable, List, Optional


class BlockAllocator:
    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        # Ascending free list; allocation pops the lowest ids first, which
        # keeps live blocks clustered and defrag moves small.
        self._free: List[int] = list(range(num_blocks))
        self._owned: Dict[Hashable, List[int]] = {}
        self.peak_in_use = 0

    # ------------------------------------------------------------ queries

    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def free_blocks(self) -> int:
        return len(self._free)

    def owned_by(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # ------------------------------------------------------------ mutation

    def alloc(self, owner: Hashable, n: int) -> Optional[List[int]]:
        """Allocate n blocks for owner (appending to any it already holds).
        Returns the new block ids, or None (and no state change) when the
        pool cannot satisfy the request — admission backpressure."""
        if n < 0:
            raise ValueError(f"negative block count {n}")
        if n > len(self._free):
            return None
        ids = self._free[:n]
        del self._free[:n]
        self._owned.setdefault(owner, []).extend(ids)
        self.peak_in_use = max(self.peak_in_use, self.in_use())
        return ids

    def free(self, owner: Hashable) -> List[int]:
        """Release all blocks held by owner (no-op for unknown owners)."""
        ids = self._owned.pop(owner, [])
        if ids:
            self._free.extend(ids)
            self._free.sort()
        return ids

    def release_suffix(self, owner: Hashable, n_keep: int) -> List[int]:
        """Shrink an owner to its FIRST n_keep blocks, returning the freed
        suffix.  The block table maps logical positions to blocks in owned
        order, so a per-row length rollback frees exactly this suffix —
        the allocator half of the cache-rollback API."""
        if n_keep < 0:
            raise ValueError(f"negative n_keep {n_keep}")
        ids = self._owned.get(owner, [])
        freed = ids[n_keep:]
        if freed:
            self._owned[owner] = ids[:n_keep]
            if not self._owned[owner]:
                del self._owned[owner]
            self._free.extend(freed)
            self._free.sort()
        return freed

    def defrag(self) -> Dict[int, int]:
        """Compact live blocks into ids [0, in_use): returns {old: new} for
        every moved block and rewrites the per-owner lists in place."""
        live = sorted(b for ids in self._owned.values() for b in ids)
        moves = {old: new for new, old in enumerate(live) if old != new}
        if moves:
            for ids in self._owned.values():
                ids[:] = [moves.get(b, b) for b in ids]
            n_live = len(live)
            self._free = list(range(n_live, self.num_blocks))
        return moves
