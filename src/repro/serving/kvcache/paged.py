"""PagedKVCache: device block pools + host block-table bookkeeping.

The device side is a pytree of per-layer block pools (see
``models.attention.init_paged_kv_cache``) whose leaves all share one
physical block id space, plus nothing else — the block table itself is a
small host numpy array (max_batch, max_blocks_per_row) mirrored to device
as a fresh 2 KB-ish H2D upload on every step (async; the engine's
sync-free contract counts D2H transfers, and this is not one).

Host bookkeeping is authoritative: ``reserve`` grabs a request's worst-case
block count at admission (per-request max_len = prompt + max_new, NOT the
engine-wide max_len slab), so decode can never run out of blocks mid-flight
and exhaustion surfaces only as admission backpressure.  ``free`` returns a
finished request's blocks immediately.  ``defrag`` compacts live blocks to
the lowest pool ids and permutes the device pools to match.

Mesh sharding (``dp_shards > 1`` + an active ``par``): the pools shard over
their BLOCK dim across the DP mesh axes and the block id space partitions
into per-shard ranges in lockstep — engine slot ``s`` maps to DP shard
``s * dp_shards // max_batch`` and only ever reserves blocks from that
shard's range, so every row's pool reads/writes stay device-local and the
host allocator stays authoritative per shard (its own free list,
backpressure, and peak).  ``defrag`` moves are shard-local by construction,
so the donated device permutation is block-diagonal over the mesh.  With
``dp_shards == 1`` (or no mesh) everything reduces bit-for-bit to the
single-device layout."""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .allocator import BlockAllocator


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def resolve_num_blocks(max_batch: int, max_len: int, block_size: int,
                       num_blocks: Optional[int] = None,
                       dp_shards: int = 1) -> int:
    """The pool size PagedKVCache actually allocates for these knobs.

    Shared with launch/steps.RootContext so the static auditor traces jit
    roots against EXACTLY the pool geometry the engine will build — the
    default (dense-slab capacity parity) and the DP rounding live here and
    nowhere else."""
    if num_blocks is None:
        # Capacity parity with the dense slab by default; size it down
        # (expected live tokens / block_size) to realize the HBM win.
        num_blocks = _ceil_div(max_batch * max_len, block_size)
    if dp_shards > 1:
        # The block dim shards over DP: round the pool up to a multiple
        # of the shard count so every device holds the same slice.
        num_blocks = _ceil_div(num_blocks, dp_shards) * dp_shards
    return num_blocks


class PagedKVCache:
    def __init__(self, model, max_batch: int, max_len: int,
                 block_size: int = 16, num_blocks: int | None = None,
                 kv_quant: bool = False, dp_shards: int = 1,
                 par=None):
        num_blocks = resolve_num_blocks(max_batch, max_len, block_size,
                                        num_blocks, dp_shards)
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.dp_shards = dp_shards
        self.max_batch = max_batch
        self.max_blocks_per_row = _ceil_div(max_len, block_size)
        self.pools = model.init_paged_cache(num_blocks, block_size,
                                            kv_quant=kv_quant)
        self.alloc = BlockAllocator(num_blocks, num_shards=dp_shards)
        self.table_np = np.full((max_batch, self.max_blocks_per_row), -1,
                                np.int32)
        # Device mirror of the block table, rebuilt lazily only when a
        # reservation/free/rollback/defrag rewrites table_np — the table
        # is loop-invariant between those events, so the decode hot path
        # must not pay a fresh H2D upload per dispatch.  ``table_sharding``
        # (set by the engine under a mesh) pins the mirror's placement so
        # sharded jit roots see their exact expected in_sharding.
        self._table_dev = None
        self.table_sharding = None

        # Per-leaf block axis, found structurally (models.api probe —
        # scanned layer stacks carry a leading (repeats,) dim, so the axis
        # is not fixed, and shape sniffing would misfire when repeats ==
        # num_blocks).
        from repro.models.api import paged_cache_block_axes

        block_axes = paged_cache_block_axes(model, num_blocks, block_size,
                                            kv_quant=kv_quant)
        self.block_axes = block_axes

        # Mesh placement: pools shard over their block dim on the DP axes
        # (replicated over TP) — the engine reuses ``self.shardings`` to pin
        # its jit roots' pool in/out shardings.
        self.shardings = None
        permute_kw: Dict[str, Any] = {}
        if par is not None and getattr(par, "active", False):
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.models.api import serving_cache_pspecs

            if dp_shards > 1:
                pspecs = serving_cache_pspecs(
                    model, par, num_blocks=num_blocks,
                    block_size=block_size, kv_quant=kv_quant,
                    axes=block_axes, shapes=self.pools,
                )
            else:
                # Host bookkeeping is single-shard (e.g. max_batch doesn't
                # divide DP): keep the pools replicated so the device
                # layout matches the allocator's view.
                pspecs = jax.tree.map(lambda leaf: P(), self.pools)
            self.shardings = jax.tree.map(
                lambda s: NamedSharding(par.mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            self.pools = jax.device_put(self.pools, self.shardings)
            permute_kw = {
                "in_shardings": (self.shardings,
                                 NamedSharding(par.mesh, P())),
                "out_shardings": self.shardings,
            }

        self._permute = jax.jit(
            lambda pools, perm: jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, perm, axis=ax),
                pools, block_axes,
            ),
            donate_argnums=(0,), **permute_kw,
        )

    # ----------------------------------------------------------- blocks

    @property
    def blocks_per_shard(self) -> int:
        return self.num_blocks // self.dp_shards

    def slot_shard(self, slot: int) -> int:
        """DP shard owning engine slot ``slot`` (slots partition evenly and
        contiguously over shards, mirroring the batch-dim sharding of the
        engine's per-slot state)."""
        return slot * self.dp_shards // self.max_batch

    def blocks_for(self, n_tokens: int) -> int:
        return _ceil_div(max(1, n_tokens), self.block_size)

    def can_reserve(self, n_tokens: int, slot: int = 0) -> bool:
        return self.alloc.can_alloc(self.blocks_for(n_tokens),
                                    shard=self.slot_shard(slot))

    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Reserve blocks covering n_tokens for engine slot ``slot`` from
        the slot's DP shard.  False (no state change) when that shard is
        exhausted."""
        n = self.blocks_for(n_tokens)
        if n > self.max_blocks_per_row:
            raise ValueError(
                f"{n_tokens} tokens need {n} blocks > "
                f"max_blocks_per_row={self.max_blocks_per_row}"
            )
        if self.alloc.alloc(slot, n, shard=self.slot_shard(slot)) is None:
            return False
        owned = self.alloc.owned_by(slot)  # appends compose correctly
        self.table_np[slot, :] = -1
        self.table_np[slot, : len(owned)] = owned
        self._table_dirty()
        return True

    def extend(self, slot: int, n_tokens: int) -> Optional[int]:
        """Grow slot's reservation to cover ``n_tokens`` logical positions
        — the allocate-on-demand path.  Returns the number of blocks
        appended (0 when coverage already suffices), or None (no state
        change) when the slot's shard is dry: the scheduler then stalls
        the row or preempts a victim.  Appended blocks extend the table
        row in owned order, so positions already written stay mapped."""
        have = len(self.alloc.owned_by(slot))
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks_per_row:
            raise ValueError(
                f"{n_tokens} tokens need {need} blocks > "
                f"max_blocks_per_row={self.max_blocks_per_row}"
            )
        if need <= have:
            return 0
        ids = self.alloc.grow(slot, need - have,
                              shard=self.slot_shard(slot))
        if ids is None:
            return None
        self.table_np[slot, have:have + len(ids)] = ids
        self._table_dirty()
        return len(ids)

    def free(self, slot: int) -> List[int]:
        """Release a finished slot's blocks immediately for reuse."""
        self.table_np[slot, :] = -1
        self._table_dirty()
        return self.alloc.free(slot)

    def rollback(self, slot: int, n_tokens: int) -> List[int]:
        """Cache-rollback API: shrink a slot's reservation to cover only
        ``n_tokens`` logical positions, freeing the block suffix and
        clearing its table entries.  Device pools need no touch — entries
        past a row's cache_len are already invisible to attention; the
        block table is the paged layout's write cursor.

        Speculative decoding's per-step rollback is pure length arithmetic
        (worst-case reservations stay put for the request's lifetime);
        this entry point is for callers that shrink a row's WORST CASE
        mid-flight — e.g. allocate-on-demand admission or preemption.
        ``n_tokens == 0`` releases everything (victim eviction)."""
        n_keep = 0 if n_tokens <= 0 else self.blocks_for(n_tokens)
        freed = self.alloc.release_suffix(slot, n_keep)
        if freed:
            owned = self.alloc.owned_by(slot)
            self.table_np[slot, :] = -1
            self.table_np[slot, : len(owned)] = owned
            self._table_dirty()
        return freed

    def _table_dirty(self) -> None:
        self._table_dev = None

    def table_device(self) -> jax.Array:
        if self._table_dev is None:
            # Explicit device_put (not jnp.asarray) so rebuilding the
            # mirror inside a jax.transfer_guard("disallow") region is a
            # sanctioned transfer — the guard exists to catch IMPLICIT ones.
            self._table_dev = jax.device_put(self.table_np,
                                             self.table_sharding)
        return self._table_dev

    # ----------------------------------------------------------- defrag

    def defrag(self) -> Dict[int, int]:
        """Compact live blocks to the lowest pool ids of their shard range;
        permutes the device pools (donated, shard-local gather) and rewrites
        the host block table."""
        moves = self.alloc.defrag()
        if not moves:
            return moves
        perm = np.arange(self.num_blocks)
        for old, new in moves.items():
            perm[new] = old
        self.pools = self._permute(self.pools, jnp.asarray(perm))
        remap = np.vectorize(lambda b: moves.get(b, b))
        live = self.table_np >= 0
        self.table_np[live] = remap(self.table_np[live])
        self._table_dirty()
        return moves

    # ------------------------------------------------------------ stats

    def hbm_bytes(self) -> int:
        return int(sum(leaf.nbytes for leaf in jax.tree.leaves(self.pools)))

    def stats(self) -> Dict[str, Any]:
        s = {
            "block_size": self.block_size,
            "num_blocks": self.num_blocks,
            "blocks_in_use": self.alloc.in_use(),
            "blocks_peak": self.alloc.peak_in_use,
            "tokens_capacity": self.num_blocks * self.block_size,
            "tokens_reserved": self.alloc.in_use() * self.block_size,
            "cache_hbm_bytes": self.hbm_bytes(),
            "dp_shards": self.dp_shards,
            "per_device_cache_hbm_bytes":
                self.hbm_bytes() // self.dp_shards,
        }
        if self.dp_shards > 1:
            # Per-shard truth: a device's peak cache residency is ITS
            # shard's peak, not aggregate/dp (shards peak independently).
            s["blocks_peak_by_shard"] = list(self.alloc.peak_by_shard)
            s["blocks_per_shard"] = self.blocks_per_shard
        return s
