"""Deterministic fault injection + degradation policy for the serving engine.

The serving stack for aggressively compressed models (NSVD at low ratio,
int8 dequant in-kernel, a higher-compression draft twin) operates near
numerical cliffs, so the engine treats faults as a first-class input: a
seeded :class:`FaultPlan` injects each failure mode at a chosen engine
step, and the engine's always-on degradation machinery (device-side
finite check, swap checksums, draft cool-down, deadline shedding, the
step-time watchdog) must absorb it without perturbing any healthy row's
token stream.

Like ``NULL_TELEMETRY``, the harness is a pure test/chaos surface: an
engine constructed without a plan takes no extra branches on the hot
path beyond a single ``is None`` check per injection site, and the
chaos-variant roots (which carry an extra poison input) are only built
when the plan contains a ``poison_logits`` spec.

Fault kinds
-----------
``poison_logits``
    Add a NaN to the targeted request's logits at the chosen step, via
    the chaos-variant root's trailing poison input.  The device-side
    finite check folds the verdict into the packed D2H word
    (``POISON_TOKEN`` for decode, ``n_commit == -1`` for spec verify),
    so detection needs no extra transfer.  Requires ``uid``.  Fires at
    the first dispatch at/after ``step`` where the row is live and
    unstalled; a uid that never reaches the device leaves the spec
    unfired (see :meth:`FaultPlan.outstanding`).
``alloc_fail``
    Fail the next ``BlockAllocator`` reservation (admission) or grow
    attempt at/after ``step``.  Admission retries the next round; a
    live row stalls exactly like a genuinely dry pool.
``swap_corrupt``
    Flip one byte in the next swap-out payload at/after ``step``
    (optionally matched to ``uid``).  The checksum mismatch at resume
    falls back to reprefill-resume.
``straggler``
    Sleep ``delay_s`` before the next D2H sync at/after ``step``,
    simulating a hung transfer; the watchdog flags it.
``draft_kill``
    Raise inside the next speculative draft dispatch at/after ``step``;
    the engine degrades to plain decode and re-enables the draft after
    a cool-down.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.straggler import StragglerConfig

FAULT_KINDS = (
    "poison_logits",
    "alloc_fail",
    "swap_corrupt",
    "straggler",
    "draft_kill",
)

#: finish_reason values a Request can end with.
FINISH_REASONS = ("stop", "error", "deadline", "cancelled", "shutdown")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    step: engine dispatch-step counter at/after which the fault fires
        (each spec fires at most once, at the first opportunity).
    uid: target request (required for poison_logits; optional filter
        for swap_corrupt; ignored otherwise).
    delay_s: straggler sleep duration.
    """

    kind: str
    step: int = 0
    uid: Optional[int] = None
    delay_s: float = 0.25

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.kind == "poison_logits" and self.uid is None:
            raise ValueError("poison_logits requires a target uid")
        if self.step < 0:
            raise ValueError("step must be >= 0")
        if self.delay_s < 0:
            raise ValueError("delay_s must be >= 0")


class FaultPlan:
    """A seeded, deterministic set of faults consumed by the engine.

    The plan is pure bookkeeping: the engine asks ``take(kind, step,
    uid=...)`` at each injection site and a matching unfired spec is
    returned (and marked fired) or None.  ``counts()`` reports fired
    faults by kind — the accounting the tests and BENCH stamps check
    against the engine's quarantine/retry/shed counters.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)
        self._fired = [False] * len(self.specs)
        self.fired_log: List[Tuple[FaultSpec, int]] = []

    def __len__(self) -> int:
        return len(self.specs)

    def has(self, kind: str) -> bool:
        return any(s.kind == kind for s in self.specs)

    def take(self, kind: str, step: int,
             uid: Optional[int] = None) -> Optional[FaultSpec]:
        """Claim the first unfired spec of ``kind`` due at ``step``.

        For uid-matched kinds, a spec with uid=None matches any request
        while a spec with a uid only matches that request.
        """
        for i, sp in enumerate(self.specs):
            if self._fired[i] or sp.kind != kind or step < sp.step:
                continue
            if sp.uid is not None and uid is not None and sp.uid != uid:
                continue
            if sp.uid is not None and uid is None:
                continue
            self._fired[i] = True
            self.fired_log.append((sp, step))
            return sp
        return None

    def counts(self) -> Dict[str, int]:
        """Fired-fault counts by kind (only kinds that fired appear)."""
        out: Dict[str, int] = {}
        for sp, _ in self.fired_log:
            out[sp.kind] = out.get(sp.kind, 0) + 1
        return out

    def outstanding(self) -> List[FaultSpec]:
        """Specs that never found an injection site."""
        return [sp for i, sp in enumerate(self.specs) if not self._fired[i]]

    # -- JSON (the serve CLI's --chaos PLAN.json) -----------------------
    def to_json(self) -> str:
        return json.dumps({"faults": [dataclasses.asdict(s)
                                      for s in self.specs]}, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            doc = json.load(f)
        raw = doc["faults"] if isinstance(doc, dict) else doc
        return cls([FaultSpec(**{k: v for k, v in s.items()
                                 if v is not None}) for s in raw])


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Degradation knobs: what the engine does once a fault is detected.

    max_retries: poisoned requests retry (reprefill from committed
        context) up to this many times before retiring with
        ``finish_reason="error"``.  0 quarantines immediately.
    retry_backoff_steps / retry_backoff_cap: capped exponential backoff
        in engine steps between retries (base * 2**(attempt-1)).
    draft_cooldown_steps: plain-decode steps before a killed draft path
        is re-enabled.
    step_timeout_s: hard per-step wall-clock limit (dispatch + sync);
        exceeding it raises a structured :class:`ServingFault` with an
        engine snapshot.  None disables the hard limit.
    straggler: watchdog thresholds for soft slow-step detection.
    """

    max_retries: int = 0
    retry_backoff_steps: int = 4
    retry_backoff_cap: int = 64
    draft_cooldown_steps: int = 16
    step_timeout_s: Optional[float] = None
    straggler: StragglerConfig = dataclasses.field(
        default_factory=StragglerConfig)

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_backoff_steps < 1 or self.retry_backoff_cap < 1:
            raise ValueError("retry backoff must be >= 1 step")

    def backoff(self, attempt: int) -> int:
        """Park duration in engine steps for retry number ``attempt``."""
        return min(self.retry_backoff_cap,
                   self.retry_backoff_steps * (2 ** max(0, attempt - 1)))


class ServingFault(RuntimeError):
    """A structured, post-mortem-friendly engine failure.

    Raised when degradation cannot contain a fault (today: the hard
    step-timeout).  Carries the fault kind, the engine step, and a
    JSON-serializable engine-state snapshot for post-mortem.
    """

    def __init__(self, message: str, kind: str, step: int,
                 snapshot: Optional[dict] = None):
        super().__init__(message)
        self.kind = kind
        self.step = step
        self.snapshot = snapshot or {}


class ServingFaultHandler:
    """Serving adaptation of :class:`repro.runtime.fault.FaultHandler`.

    The training handler counts consecutive bad *steps* against one
    model; serving quarantines per *request*.  This tracks per-uid
    retry budgets and total dispositions so the engine's accounting has
    one owner.
    """

    def __init__(self, policy: FaultPolicy):
        self.policy = policy
        self.quarantined = 0
        self.retried = 0

    def disposition(self, req) -> Tuple[str, int]:
        """('retry', backoff_steps) or ('quarantine', 0) for a poisoned
        request.  Mutates ``req.retries`` on retry."""
        if req.retries < self.policy.max_retries:
            req.retries += 1
            self.retried += 1
            return "retry", self.policy.backoff(req.retries)
        self.quarantined += 1
        return "quarantine", 0
