"""DraftState: the draft model's serving-side state.

The draft runs the SAME architecture as the target (self-speculative NSVD:
identical shapes, cheaper factored matmuls), so its cache leaves are
shape-identical to the target's and it can mirror the engine's slot layout
one-for-one.  Three invariants keep the state tiny:

  * ``cache_len`` and ``last_token`` are SHARED with the target engine —
    they are equal by construction after prefill (both caches hold the
    prompt; the first sampled token is pending) and after every spec step
    (the verify step rolls BOTH caches' lengths to the accepted prefix
    n + m + 1 and both feed the same correction/bonus token next).  The
    draft-K root feeds all k+1 drafted tokens through the draft (one more
    forward than it samples), so the draft cache always holds an entry for
    every committed token — no catch-up chunk is ever needed.
  * Only the cache itself and the draft PRNG keys are draft-private.
  * Paged mode reserves blocks in lockstep with the target: a request is
    admitted only when BOTH pools can hold its worst case, so neither side
    can run out mid-flight.

Mesh sharding: the draft cache inherits the TARGET's shardings by
construction — same ``dp_shards``/``par`` flow into its ``PagedKVCache``
(block-dim DP pools, per-shard host allocator), and for the dense slab the
engine passes the target slab's ``cache_shardings``/``key_sharding``
verbatim (identical leaf shapes, so the same tree applies).
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.serving.kvcache import PagedKVCache


class DraftState:
    def __init__(self, model, params: Any, max_batch: int, max_len: int,
                 paged: bool, block_size: int = 16,
                 num_blocks: Optional[int] = None, kv_quant: bool = False,
                 seed: int = 1234, dp_shards: int = 1, par=None,
                 cache_shardings=None, key_sharding=None):
        self.params = params
        self.paged = paged
        if paged:
            self.kv = PagedKVCache(model, max_batch, max_len,
                                   block_size=block_size,
                                   num_blocks=num_blocks, kv_quant=kv_quant,
                                   dp_shards=dp_shards, par=par)
            self.cache = None
        else:
            self.kv = None
            self.cache = model.init_cache(max_batch, max_len,
                                          kv_quant=kv_quant)
            if cache_shardings is not None:
                self.cache = jax.device_put(self.cache, cache_shardings)
        self.key_data = jax.random.key_data(
            jax.random.split(jax.random.key(seed), max_batch)
        )
        if key_sharding is not None:
            self.key_data = jax.device_put(self.key_data, key_sharding)

    # ---------------------------------------------------------- block ops

    def reserve(self, slot: int, n_tokens: int) -> bool:
        return self.kv.reserve(slot, n_tokens) if self.paged else True

    def extend(self, slot: int, n_tokens: int) -> Optional[int]:
        """Grow the draft reservation in lockstep with the target's
        on-demand growth (0 blocks for the dense slab)."""
        return self.kv.extend(slot, n_tokens) if self.paged else 0

    def rollback(self, slot: int, n_tokens: int) -> None:
        """Shrink the draft reservation with the target's (preemption)."""
        if self.paged:
            self.kv.rollback(slot, n_tokens)

    def free(self, slot: int) -> None:
        if self.paged:
            self.kv.free(slot)

    def hbm_bytes(self) -> int:
        leaves = jax.tree.leaves(self.kv.pools if self.paged else self.cache)
        return int(sum(leaf.nbytes for leaf in leaves))

    def table_device(self) -> Optional[jax.Array]:
        return self.kv.table_device() if self.paged else None

    @property
    def pools(self):
        """The draft cache pytree, whichever layout backs it."""
        return self.kv.pools if self.paged else self.cache

    @pools.setter
    def pools(self, value):
        if self.paged:
            self.kv.pools = value
        else:
            self.cache = value
