"""Device-side speculative verification: batched accept/resample.

Pure jnp functions shared by the ``make_spec_verify_step`` jit root
(launch/steps.py) and the distribution tests — the statistical guarantee
(temperature > 0 rejection sampling preserves the target distribution
exactly, Leviathan et al. 2023) is pinned against ``verify_tail`` directly.

Chunk indexing convention (K = number of draft proposals):

    chunk fed to the target = [t0, d_1, ..., d_K]        (B, K+1) tokens
    target logits L_i at chunk index i = distribution of the token AFTER
    the prefix ending at chunk[i]; so P_{i-1} = softmax(L_{i-1}/tau) is the
    target distribution d_i is judged against, and q[i-1] (0-based) is the
    draft distribution d_i was sampled from.

Acceptance: greedy rows (temp <= 0) accept d_i iff argmax(L_{i-1}) == d_i
(exact prefix match — token-identical to non-speculative greedy decode by
induction).  Temperature rows accept d_i with probability
min(1, P_{i-1}(d_i)/q_{i-1}(d_i)), drawn as u * q < p to avoid the divide.
After the accepted prefix of length m: a probabilistic rejection resamples
from norm(max(P_m - q_m, 0)); a full window (m == min(K, k_row), no
rejection event) samples the bonus token from P_m directly — the k_row
cutoff is a scheduling decision, not a rejection, so the residual formula
would bias it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _row_verify(kd, logits_r, q_r, d_r, temp, kr):
    """Single-row accept/resample.  logits_r: (K+1, V) target logits over
    the chunk, q_r: (K, V) draft probs, d_r: (K,) proposals, kr: row's
    speculation window (1..K).  Returns (new key_data, m, t_new)."""
    k = d_r.shape[0]
    ar = jnp.arange(k)
    greedy_tok = jnp.argmax(logits_r, axis=-1).astype(jnp.int32)  # (K+1,)
    p = jax.nn.softmax(
        logits_r.astype(jnp.float32) / jnp.maximum(temp, 1e-6), axis=-1
    )  # (K+1, V)

    key, sub = jax.random.split(jax.random.wrap_key_data(kd))
    k_u, k_r = jax.random.split(sub)
    u = jax.random.uniform(k_u, (k,))

    p_d = p[ar, d_r]  # P_{i-1}(d_i)
    q_d = q_r[ar, d_r]  # q_{i-1}(d_i)
    acc = jnp.where(temp > 0.0, u * q_d < p_d, greedy_tok[:k] == d_r)
    acc = jnp.logical_and(acc, ar < kr)
    m = jnp.cumprod(acc.astype(jnp.int32)).sum()  # accepted prefix length

    p_m = p[m]  # target dist after the accepted prefix
    q_m = q_r[jnp.minimum(m, k - 1)]  # draft dist of the REJECTED position
    resid = jnp.maximum(p_m - q_m, 0.0)
    resid = jnp.where(resid.sum() > 0.0, resid, p_m)  # numerical guard
    full = m == jnp.minimum(kr, k)  # window exhausted, no rejection event
    dist = jnp.where(full, p_m, resid)
    drawn = jax.random.categorical(k_r, jnp.log(dist + 1e-30)).astype(jnp.int32)
    t_new = jnp.where(temp > 0.0, drawn, greedy_tok[m])
    return jax.random.key_data(key), m, t_new


def verify_tail(key_data, logits, q_probs, proposals, temps, k_row):
    """Batched accept/resample over a verification chunk.

    key_data: (B, 2) uint32, logits: (B, K+1, V) target logits over
    [t0, d_1..d_K], q_probs: (B, K, V) draft probs, proposals: (B, K),
    temps: (B,), k_row: (B,) per-row speculation window.

    Returns (new key_data, m (B,) accepted counts, t_new (B,) the
    correction/bonus token, out_tokens (B, K+1) the committed-token matrix
    [d_1..d_m, t_new, <t_new fill>]).
    """
    key_data, m, t_new = jax.vmap(_row_verify)(
        key_data, logits, q_probs, proposals, temps, k_row
    )
    k = proposals.shape[1]
    idx = jnp.arange(k + 1)[None, :]
    padded = jnp.concatenate([proposals, proposals[:, -1:]], axis=1)
    out_tokens = jnp.where(idx < m[:, None], padded, t_new[:, None])
    return key_data, m, t_new, out_tokens
