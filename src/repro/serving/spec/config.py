"""Speculative-decoding configuration for the serving engine."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class SpecConfig:
    """Self-speculative decoding: a higher-compression NSVD twin (or any
    same-architecture params pytree) drafts ``k`` tokens per engine step;
    the target verifies them in one chunk-decode call and commits the
    accepted prefix plus one correction/bonus token.

    draft_params: params pytree for the draft forward pass.  Same model
        object as the target — NSVD-factored leaves dispatch through
        ``linear_apply`` like any compressed checkpoint.  Build one from a
        compression plan with ``models.api.build_draft_params``.
    k: speculation window — draft tokens proposed per engine step.  Each
        step commits between 1 and k+1 tokens.
    dynamic_k: per-row adaptive window.  Rows start at ``k``; a step that
        accepts its whole window grows the row's window by one (capped at
        ``k``), a step that accepts nothing shrinks it (floored at 1).
        Shapes stay fixed — the window masks acceptance, it does not shrink
        the draft loop — so this trades committed tokens for acceptance
        rate, not FLOPs.
    seed: draft-side PRNG seed (independent of the target's sampling keys:
        proposals consume draft keys, accept/resample consumes target keys).
    draft_ratio: OPTIONAL metadata — the NSVD compression ratio the draft
        was built at.  Never consulted by the decode path; it keys the
        observability layer's spec-acceptance histogram (win/loss per
        (k, draft-ratio) in the bench history, the signal ROADMAP item 5's
        dynamic-k controller consumes).
    """

    draft_params: Any
    k: int = 4
    dynamic_k: bool = False
    seed: int = 1234
    draft_ratio: Optional[float] = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
