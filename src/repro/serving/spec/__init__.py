"""Self-speculative decoding subsystem.

NSVD's training-free compression sweep gives every checkpoint a free draft
model: the same weights at a higher compression ratio.  This package pairs
that draft with the target inside the serving engine — the draft proposes
``k`` tokens per step (one fused jit root, K sequential cheap decodes), the
target verifies them in a single S>1 chunk-decode call (the same root shape
chunked prefill uses), and batched accept/resample on device commits the
accepted prefix plus one correction/bonus token, rolling both caches' per-
row lengths back to the committed prefix.

Pieces:
  config.SpecConfig  — k, dynamic per-row windows, draft params/seed
  draft.DraftState   — draft-side cache (paged or dense) + PRNG keys
  verify.verify_tail — batched greedy / Leviathan accept-resample math

The jit roots live in launch/steps.py (make_spec_draft_step /
make_spec_verify_step / the draft prefill twins); serving/engine.py wires
them into step() and admission."""

from .config import SpecConfig
from .draft import DraftState
from .verify import verify_tail

__all__ = ["SpecConfig", "DraftState", "verify_tail"]
