"""Scheduling policy for the continuous-batching engine.

The engine owns device state and executes steps; this module owns every
scheduling *decision*: which queued request is admitted next (per-class
SLA queues with starvation-free aging), how many blocks admission must
cover (on-demand = prompt only, worst-case = prompt + max_new), where a
new row lands on a DP mesh (emptiest shard's sub-pool), which live row
is evicted when the pool runs dry (most-blocks victim, matching the
``preempt_ready`` observability flag), and in what order decode rows are
packed for dispatch (longest-first per shard, so the packed
paged-attention kernel's shared page loop runs ragged packs less often).

Requests are duck-typed: the scheduler reads ``uid``, ``class_idx``,
``generated``, ``max_new_tokens`` and the engine-maintained
``prefix_len`` (prompt length, or saved context length for a
swap-resumed row).  It never touches device state.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

ADMISSION_POLICIES = ("on_demand", "worst_case")
RESUME_MODES = ("reprefill", "swap")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Policy knobs for the continuous-batching scheduler.

    admission: "on_demand" admits a request on blocks for its prompt
        alone and grows the reservation at block boundaries as the row
        decodes, so pool occupancy tracks live tokens.  "worst_case"
        reserves prompt + max_new up front (the pre-scheduler contract,
        kept for bit-compat pins and as a no-surprises fallback).
    preempt: allow evicting a live row (most blocks first) when block
        growth or a higher-priority admission cannot be satisfied.  Off,
        a starved row stalls (frozen on device) until blocks free up,
        and a full-pool deadlock raises instead of thrashing.
    resume: how a preempted request comes back.  "reprefill" re-runs
        prefill over prompt + generated (cheap bookkeeping, recompute on
        resume); "swap" copies the victim's KV blocks to host and
        scatters them back on re-admission (no recompute, preserves the
        sampling-key chain; unsupported with speculative decoding).
    priority_classes: latency classes, highest priority first.
        ``submit(latency_class=...)`` names one; None maps to the last
        (lowest) class.  A single class degenerates to FIFO.
    aging_rounds: a queued class-head gains one priority rank per this
        many blocked admission rounds, so low classes cannot starve.
        0 disables aging.
    sort_decode_rows: pack decode rows longest-first within each DP
        shard before dispatch (token streams are invariant under the
        permutation; pinned by tests).
    """

    admission: str = "on_demand"
    preempt: bool = True
    resume: str = "reprefill"
    priority_classes: Tuple[str, ...] = ("default",)
    aging_rounds: int = 32
    sort_decode_rows: bool = True

    def __post_init__(self):
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission must be one of {ADMISSION_POLICIES}, "
                f"got {self.admission!r}")
        if self.resume not in RESUME_MODES:
            raise ValueError(
                f"resume must be one of {RESUME_MODES}, got {self.resume!r}")
        if not self.priority_classes:
            raise ValueError("priority_classes must be non-empty")
        if len(set(self.priority_classes)) != len(self.priority_classes):
            raise ValueError("priority_classes must be unique")
        if self.aging_rounds < 0:
            raise ValueError("aging_rounds must be >= 0")


class Scheduler:
    """Per-class admission queues + placement/victim policy.

    The queues hold engine ``Request`` objects.  ``head()`` is the
    admission candidate: the front of the best effective-priority class,
    where a class-head's effective priority improves by one rank per
    ``aging_rounds`` blocked admission rounds (``note_blocked()``).
    Resumed requests re-enter at the FRONT of their class — a preempted
    row outranks everything queued behind it at equal class.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.cfg = config or SchedulerConfig()
        self._queues: Tuple[Deque, ...] = tuple(
            deque() for _ in self.cfg.priority_classes)
        self._wait_rounds: List[int] = [0] * len(self.cfg.priority_classes)
        self._seq = 0

    # -- config views ---------------------------------------------------
    @property
    def on_demand(self) -> bool:
        return self.cfg.admission == "on_demand"

    @property
    def preempt(self) -> bool:
        return self.cfg.preempt

    @property
    def resume_mode(self) -> str:
        return self.cfg.resume

    @property
    def sort_decode_rows(self) -> bool:
        return self.cfg.sort_decode_rows

    def class_index(self, latency_class: Optional[str]) -> int:
        """Map a submit()-supplied class name to its queue index."""
        if latency_class is None:
            return len(self.cfg.priority_classes) - 1
        try:
            return self.cfg.priority_classes.index(latency_class)
        except ValueError:
            raise ValueError(
                f"unknown latency class {latency_class!r}; configured "
                f"classes: {self.cfg.priority_classes}") from None

    # -- queue ops ------------------------------------------------------
    def submit(self, req) -> None:
        req._sched_seq = self._seq
        self._seq += 1
        self._queues[req.class_idx].append(req)

    def requeue(self, req) -> None:
        """Re-admit a preempted request at the front of its class."""
        self._queues[req.class_idx].appendleft(req)

    def remove(self, uid: int):
        """Pull a queued request out by uid (cancel / deadline shed).

        Returns the removed request, or None if no queued request has
        that uid.  Relative order of everything else is preserved."""
        for q in self._queues:
            for req in q:
                if req.uid == uid:
                    q.remove(req)
                    return req
        return None

    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    def __bool__(self) -> bool:
        return self.pending() > 0

    def __len__(self) -> int:
        return self.pending()

    def queued(self) -> List:
        """All queued requests, admission order (best class first)."""
        order = sorted(range(len(self._queues)),
                       key=lambda c: self._effective(c))
        out: List = []
        for c in order:
            out.extend(self._queues[c])
        return out

    def _effective(self, class_idx: int) -> Tuple[int, int]:
        """Effective rank of a class-head: smaller admits first."""
        rank = class_idx
        if self.cfg.aging_rounds:
            rank -= self._wait_rounds[class_idx] // self.cfg.aging_rounds
        q = self._queues[class_idx]
        seq = q[0]._sched_seq if q else 0
        return (max(0, rank), seq)

    def head(self):
        """The next admission candidate, or None if nothing is queued."""
        best = None
        best_key = None
        for c, q in enumerate(self._queues):
            if not q:
                continue
            key = self._effective(c)
            if best_key is None or key < best_key:
                best, best_key = q[0], key
        return best

    def pop_head(self):
        head = self.head()
        if head is None:
            raise IndexError("pop_head on empty scheduler")
        self._queues[head.class_idx].popleft()
        self._wait_rounds[head.class_idx] = 0
        return head

    def note_blocked(self) -> None:
        """One blocked admission round: age every waiting class-head."""
        if not self.cfg.aging_rounds:
            return
        for c, q in enumerate(self._queues):
            if q:
                self._wait_rounds[c] += 1

    def take_bucket(self, max_r: int, bucket_of) -> List:
        """Pop up to ``max_r`` requests sharing the head's bucket.

        Scans the head's class queue FIFO (non-matching requests keep
        their relative order) — the dense engine's batched-prefill
        grouping, now per latency class.  ``bucket_of(req)`` is the
        engine's prompt-length bucket function."""
        head = self.head()
        if head is None:
            return []
        q = self._queues[head.class_idx]
        want = bucket_of(head)
        group: List = []
        rest: Deque = deque()
        while q:
            req = q.popleft()
            if len(group) < max_r and bucket_of(req) == want:
                group.append(req)
            else:
                rest.append(req)
        q.extend(rest)
        if group:
            self._wait_rounds[head.class_idx] = 0
        return group

    # -- admission sizing ----------------------------------------------
    def admit_tokens(self, req, max_len: int) -> int:
        """Tokens admission must cover before the row can activate.

        on_demand: the request's current prefix (prompt, or saved
        context for a swap resume) — growth covers the rest.
        worst_case: prefix plus every token the row could still emit.
        """
        prefix = req.prefix_len
        if self.on_demand:
            return prefix
        remaining = req.max_new_tokens - len(req.generated)
        return min(max_len, prefix + remaining)

    # -- placement ------------------------------------------------------
    def slot_order(self, free_slots: Sequence[int], kv,
                   freed_at: Sequence[int]) -> List[int]:
        """Order free slots for admission: emptiest DP shard first.

        Ties (always, on a 1-shard pool) fall back to freed-order, which
        is exactly the pre-scheduler handout — so single-shard admission
        is bit-identical to the old first-free scan.
        """
        alloc = kv.alloc
        return sorted(
            free_slots,
            key=lambda s: (-alloc.free_blocks(kv.slot_shard(s)),
                           freed_at[s]))

    # -- preemption -----------------------------------------------------
    def pick_victim(self, candidates: Sequence[Tuple[int, int, int]]
                    ) -> Optional[int]:
        """Pick the eviction victim from (slot, owned_blocks, class_idx).

        Most-blocks first (the row whose eviction frees the most pool,
        and the same row the ``preempt_ready`` hook flags), breaking
        ties toward the lower-priority class, then the higher slot.
        """
        if not candidates:
            return None
        slot, _, _ = max(candidates, key=lambda c: (c[1], c[2], c[0]))
        return slot

    def row_order(self, dev_len, eff_active, max_batch: int,
                  dp_shards: int):
        """Dispatch-order permutation of decode rows, or None to skip.

        Within each DP shard's contiguous slot range, live rows sort by
        device cache length descending (stable), dead/stalled rows sink
        to the end — so each packed-kernel row pack shares page-loop
        trip counts instead of the longest row dragging short ones.
        """
        if not self.cfg.sort_decode_rows:
            return None
        import numpy as np

        order = np.empty(max_batch, np.int32)
        per = max_batch // dp_shards
        for s in range(dp_shards):
            lo = s * per
            hi = lo + per
            keys = np.where(eff_active[lo:hi], dev_len[lo:hi], -1)
            order[lo:hi] = lo + np.argsort(-keys, kind="stable")
        return order
