"""Continuous-batching scheduler: admission, block growth, preemption.

The engine executes; the scheduler decides.  See scheduler.py for the
policy surface (admission policy, priority classes, victim selection,
DP-aware placement) and SchedulerConfig for the knobs.
"""

from repro.serving.scheduler.scheduler import (  # noqa: F401
    ADMISSION_POLICIES,
    RESUME_MODES,
    Scheduler,
    SchedulerConfig,
)
