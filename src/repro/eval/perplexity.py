"""Perplexity evaluation harness (paper metric, Tables 1 & 3-6)."""

from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.losses import next_token_xent


def evaluate_ppl(
    model: Model,
    params,
    batches: Iterable[Dict[str, np.ndarray]],
    max_batches: Optional[int] = None,
) -> float:
    """exp(mean nats/token) over the stream."""

    def nll(p, batch):
        kwargs = {}
        if model.cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        elif "patches" in batch:
            kwargs["patches"] = batch["patches"]
        logits, _, _ = model.apply(p, batch["tokens"], mode="train", **kwargs)
        return next_token_xent(logits, batch["tokens"])

    jitted = jax.jit(nll)
    tot, n = 0.0, 0
    for i, batch in enumerate(batches):
        if max_batches is not None and i >= max_batches:
            break
        tot += float(jitted(params, batch))
        n += 1
    return float(np.exp(tot / max(n, 1)))


def eval_batches(vocab: int, domain: str, n_batches: int = 8, batch: int = 16,
                 seq: int = 128, seed: int = 1234):
    from repro.data.synth import DomainSampler

    sampler = DomainSampler(vocab, seed=seed)
    for _ in range(n_batches):
        yield {"tokens": sampler.batch(domain, batch, seq)}


def activation_similarity(
    model: Model, params, domain_a: str, domain_b: str, vocab: int,
    n_batches: int = 4, batch: int = 8, seq: int = 64,
) -> Dict[str, float]:
    """Paper Table 2 / Figure 1: cosine similarity between mean per-layer
    input-activation vectors of two domains."""
    from repro.data.synth import DomainSampler

    def mean_taps(domain, seed):
        sampler = DomainSampler(vocab, seed=seed)

        def fwd(p, tokens):
            taps: Dict = {}
            model.apply(p, tokens, mode="train", taps=taps)
            return {
                k: jnp.mean(jnp.abs(v.reshape(-1, v.shape[-1])), axis=0)
                for k, v in taps.items()
                if k.endswith(".in")
            }

        jitted = jax.jit(fwd)
        acc: Dict[str, np.ndarray] = {}
        for _ in range(n_batches):
            out = jitted(params, sampler.batch(domain, batch, seq))
            for k, v in out.items():
                acc[k] = acc.get(k, 0) + np.asarray(v, np.float64)
        return acc

    ta = mean_taps(domain_a, seed=11)
    tb = mean_taps(domain_b, seed=22)
    sims = {}
    for k in ta:
        a, b = ta[k], tb[k]
        denom = np.linalg.norm(a) * np.linalg.norm(b)
        sims[k] = float(a @ b / denom) if denom > 0 else 0.0
    return sims
