"""Quality-drift attribution: which layers pay for a compression ratio.

End-to-end perplexity drift (dense vs compressed) is one number; serving
it per layer needs two views, both computed here:

  * **Logit KL** — mean per-token KL(dense || test) in nats between the
    dense model's next-token distribution and a test param tree's.  Both
    forwards run inside ONE jitted function per batch, so the comparison
    sees identical inputs.
  * **Per-target patching** — for each compressed ``TargetSpec``, build a
    params tree that is dense EVERYWHERE except that one target (the
    compressed factored leaf swapped in) and measure its logit KL: the
    drift attributable to that target alone.  Patching is supported by
    construction — ``linear_apply`` dispatches per leaf on "kernel" vs
    "u", exactly how partially-compressed plans already run.

Shares of the summed per-target KL are the attribution the quality-report
CLI stamps into BENCH_quality.json.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def swap_subtree(params: Any, path: Tuple[str, ...], leaf: Any) -> Any:
    """Copy-on-path: a new tree sharing every leaf with ``params`` except
    the subtree at ``path``, which is replaced by ``leaf``."""
    if not path:
        return leaf
    out = dict(params)
    out[path[0]] = swap_subtree(params[path[0]], path[1:], leaf)
    return out


def get_subtree(params: Any, path: Tuple[str, ...]) -> Any:
    node = params
    for p in path:
        node = node[p]
    return node


def mean_logit_kl(
    model,
    params_ref: Any,
    params_test: Any,
    batches: Iterable[Dict[str, np.ndarray]],
    max_batches: Optional[int] = None,
) -> float:
    """Mean per-token KL(ref || test) over the batch stream, in nats."""

    def kl(pr, pt, batch):
        kwargs = {}
        if model.cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        elif "patches" in batch:
            kwargs["patches"] = batch["patches"]
        la, _, _ = model.apply(pr, batch["tokens"], mode="train", **kwargs)
        lb, _, _ = model.apply(pt, batch["tokens"], mode="train", **kwargs)
        la = la.astype(jnp.float32)
        lb = lb.astype(jnp.float32)
        pa = jax.nn.softmax(la, axis=-1)
        diff = jax.nn.log_softmax(la, axis=-1) - jax.nn.log_softmax(lb, axis=-1)
        return jnp.mean(jnp.sum(pa * diff, axis=-1))

    jitted = jax.jit(kl)
    tot, n = 0.0, 0
    for i, batch in enumerate(batches):
        if max_batches is not None and i >= max_batches:
            break
        tot += float(jitted(params_ref, params_test, batch))
        n += 1
    return tot / max(n, 1)


def per_target_attribution(
    model,
    dense_params: Any,
    compressed_params: Any,
    targets: Sequence,
    make_batches,
) -> List[Dict]:
    """Logit-KL of each single-target patch (dense everywhere, one
    compressed leaf swapped in), plus each target's share of the summed
    per-target KL.

    ``make_batches`` is a zero-arg callable returning a fresh batch
    iterator (the same batches must feed every patch for the deltas to be
    comparable)."""
    rows: List[Dict] = []
    for spec in targets:
        leaf = get_subtree(compressed_params, spec.path)
        patched = swap_subtree(dense_params, spec.path, leaf)
        kl = mean_logit_kl(model, dense_params, patched, make_batches())
        rows.append({"target": spec.name, "logit_kl": kl})
    total = sum(max(r["logit_kl"], 0.0) for r in rows)
    for r in rows:
        r["share"] = max(r["logit_kl"], 0.0) / total if total > 0 else 0.0
    return sorted(rows, key=lambda r: -r["logit_kl"])
