"""Synthetic multi-domain corpora (offline stand-ins for the paper's eight
datasets).

Each domain is a distinct order-2 Markov token source over a distinct token
sub-range with distinct transition temperature — giving genuinely different
activation statistics per domain (the paper's CMRC/JP regime).  Domain
similarity is measured with the paper's own activation-cosine metric in
benchmarks/table2_similarity.py to confirm the shift magnitude.

Domains:
  en_a  — "calibration language" (WikiText-2 analogue)
  en_b  — same token range, different transitions (PTB/C4 analogue)
  task  — instruction-ish mixture (SNIPS/Alpaca analogue)
  zh    — disjoint token range (CMRC-CN analogue)
  jp    — disjoint token range, different temperature (AlpacaEval-JP analogue)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DomainSpec:
    name: str
    lo: int  # token range [lo, hi)
    hi: int
    temperature: float
    seed: int
    n_states: int = 64
    perturb: float = 0.0  # mix fraction of fresh transition noise
    perturb_seed: int = 0


def default_domains(vocab: int) -> Dict[str, DomainSpec]:
    v = vocab
    return {
        # en_b shares en_a's seed: a temperature-perturbed version of the
        # SAME transition table — "same language, different corpus"
        # (PTB/C4 vs WikiText in the paper).  task overlaps half the token
        # range; zh/jp live on the disjoint upper range with much sharper
        # transition structure (different "language").
        "en_a": DomainSpec("en_a", 2, v // 2, 0.8, 101, 64),
        "en_b": DomainSpec("en_b", 2, v // 2, 1.1, 101, 64,
                           perturb=0.6, perturb_seed=777),
        "task": DomainSpec("task", v // 4, 3 * v // 4, 0.7, 303, 48),
        "zh": DomainSpec("zh", v // 2, v - 1, 0.45, 404, 32),
        "jp": DomainSpec("jp", v // 2, v - 1, 0.4, 505, 96),
    }


# Mixture weights used for pretraining the small LMs: the calibration
# language dominates (as WikiText-ish English dominates LLaMA pretraining),
# but every domain contributes enough for its embeddings/activations to be
# *structured* — which is what makes calibration-set overfitting measurable.
MIX_WEIGHTS = {"en_a": 0.55, "en_b": 0.15, "task": 0.10, "zh": 0.10, "jp": 0.10}


class MarkovSource:
    """Order-2 Markov chain with a low-rank-ish structured transition table."""

    def __init__(self, spec: DomainSpec, n_states: int = 0):
        self.spec = spec
        n_states = n_states or spec.n_states
        rng = np.random.default_rng(spec.seed)
        self.vocab_slice = np.arange(spec.lo, spec.hi)
        n = len(self.vocab_slice)
        self.n_states = n_states
        # Structured state machine: state = hash(prev2, prev1) % n_states.
        logits = rng.standard_normal((n_states, n))
        # Sparsify: each state strongly prefers a few tokens (zipfy).  The
        # boosted positions dominate the token marginals, hence the
        # activation statistics — `perturb` rewires a fraction of them
        # ("same language, different corpus": correlated but not identical).
        boost = rng.standard_normal((n_states, n)) * 2.0
        mask = rng.random((n_states, n)) < 0.08
        if spec.perturb > 0.0:
            prng = np.random.default_rng(spec.perturb_seed)
            fresh_logits = prng.standard_normal((n_states, n))
            logits = (1 - spec.perturb) * logits + spec.perturb * fresh_logits
            fresh_mask = prng.random((n_states, n)) < 0.08
            rewire = prng.random((n_states, n)) < spec.perturb
            mask = np.where(rewire, fresh_mask, mask)
        logits = logits / spec.temperature
        logits = logits + np.where(mask, boost + 5.0, 0.0)
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.probs = p / p.sum(axis=1, keepdims=True)
        self.mix_a = int(rng.integers(1, 1 << 16)) | 1
        self.mix_b = int(rng.integers(1, 1 << 16)) | 1

    def _state(self, t2: np.ndarray, t1: np.ndarray) -> np.ndarray:
        return (t2 * self.mix_a + t1 * self.mix_b) % self.n_states

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        n = len(self.vocab_slice)
        out = np.empty((batch, seq), np.int64)
        t2 = rng.integers(0, n, batch)
        t1 = rng.integers(0, n, batch)
        for j in range(seq):
            st = self._state(t2, t1)
            p = self.probs[st]
            # Vectorized categorical sampling per row.
            u = rng.random((batch, 1))
            idx = (p.cumsum(axis=1) < u).sum(axis=1).clip(0, n - 1)
            out[:, j] = idx
            t2, t1 = t1, idx
        return self.vocab_slice[out]


class DomainSampler:
    def __init__(self, vocab: int, seed: int = 0):
        self.domains = {
            k: MarkovSource(v) for k, v in default_domains(vocab).items()
        }
        self.rng = np.random.default_rng(seed)

    def batch(self, domain: str, batch: int, seq: int) -> np.ndarray:
        if domain == "mix":
            return self.mixed_batch(batch, seq)
        return self.domains[domain].sample(self.rng, batch, seq).astype(np.int32)

    def mixed_batch(self, batch: int, seq: int) -> np.ndarray:
        names = list(MIX_WEIGHTS)
        w = np.array([MIX_WEIGHTS[n] for n in names])
        rows = []
        choices = self.rng.choice(len(names), size=batch, p=w / w.sum())
        for c in choices:
            rows.append(self.domains[names[c]].sample(self.rng, 1, seq)[0])
        return np.stack(rows).astype(np.int32)

    def stream(self, domain: str, batch: int, seq: int) -> Iterator[np.ndarray]:
        while True:
            yield self.batch(domain, batch, seq)
