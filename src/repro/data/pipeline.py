"""Sharded training data pipeline.

Deterministic, restart-safe: the pipeline state is (seed, step) — a
checkpoint restores the *exact* stream position.  Batches are produced on
host (numpy), placed with the train step's input sharding, and prefetched
one step ahead so host generation overlaps device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from .synth import DomainSampler


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int
    domain: str = "en_a"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(**d)


class LMDataPipeline:
    """Next-token-prediction batches from the synthetic domain sampler."""

    def __init__(
        self,
        vocab: int,
        batch: int,
        seq: int,
        state: Optional[PipelineState] = None,
        sharding=None,
        prefetch: int = 2,
    ):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.state = state or PipelineState(seed=0, step=0)
        self.sharding = sharding
        self.prefetch = prefetch
        self._sampler = DomainSampler(vocab, seed=self.state.seed)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # --------------------------------------------------------- generation

    def _make_batch(self, step: int) -> Dict[str, np.ndarray]:
        # Per-step determinism: fold the step into the domain sampler RNG.
        rng = np.random.default_rng((self.state.seed << 20) ^ step)
        old_rng = self._sampler.rng
        self._sampler.rng = rng
        tokens = self._sampler.batch(self.state.domain, self.batch, self.seq)
        self._sampler.rng = old_rng
        return {
            "tokens": tokens,
            "loss_mask": np.ones_like(tokens, np.float32),
        }

    def _place(self, batch: Dict[str, np.ndarray]):
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.sharding[k] if isinstance(self.sharding, dict) else self.sharding)
            for k, v in batch.items()
        }

    # ----------------------------------------------------------- iterator

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        b = self._make_batch(self.state.step)
        self.state.step += 1
        return self._place(b)

    # Background prefetch (overlap host gen with device step).
    def start_prefetch(self):
        def worker():
            step = self.state.step
            while not self._stop.is_set():
                b = self._make_batch(step)
                step += 1
                self._q.put(b)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_prefetched(self) -> Dict[str, Any]:
        b = self._q.get()
        self.state.step += 1
        return self._place(b)

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                self._q.get_nowait()
