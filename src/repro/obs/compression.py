"""Compression-side observability: calibration telemetry + per-target
decomposition diagnostics.

The serving layer (PR 7) observes what the engine *does*; this module
observes what compression *did* — the paper's central mechanism (absorbing
activation outliers into the transformed weight so the nested decomposition
stays accurate) measured per layer instead of assumed:

  * **Calibration telemetry** — ``calib.runner.collect_grams`` /
    ``calib.gram.accumulate_taps`` feed per-tap activation statistics into
    the shared ``MetricsRegistry``: absmean channel distribution
    percentiles, the outlier-channel fraction at configurable thresholds
    (channels whose |mean| exceeds t× the tap mean — the "variability in
    activation distributions" the paper's abstract names), Gram condition
    numbers, accumulated sample counts, and ``min_count`` fallback usage.
  * **Decomposition diagnostics** — ``core.compress.compress_params``
    reports a ``DecompositionReport`` per ``TargetSpec``: plain vs
    activation-whitened relative Frobenius error, singular-value tail mass
    at the chosen rank, the k1/k2 nested split, the outlier-absorption
    ratio vs a rank-matched plain SVD, and achieved-vs-requested
    rank/bytes.  Aggregated into a plan-level JSON artifact
    (``CompressionTelemetry.plan_report`` / ``write_report``) and exposed
    as Prometheus families on the same registry ``--metrics-port`` serves.

Telemetry is a PURE OBSERVER: compressed params are bit-identical with
reporting on or off (pinned by tests/test_compression_obs.py).  Core stays
obs-free — ``compress_params`` talks to this object duck-typed through the
``on_*`` hooks and never imports ``repro.obs``.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import COUNT_BUCKETS, MetricsRegistry

# Outlier thresholds: a channel is an outlier at threshold t when its
# absolute mean activation exceeds t x the tap-wide channel mean (ASVD's
# working definition of the channels worth absorbing).
OUTLIER_THRESHOLDS = (2.0, 4.0, 8.0)

# Relative-error buckets for the decomposition histograms (dimensionless).
ERROR_BUCKETS = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 0.5, 1.0)


def gram_activation_stats(
    gram: np.ndarray,
    absmean: np.ndarray,
    count: float,
    thresholds: Sequence[float] = OUTLIER_THRESHOLDS,
) -> Dict:
    """Per-tap activation statistics from the accumulated (Gram, absmean).

    ``absmean`` is the per-channel mean |x| (already count-normalized, as
    ``GramStore.absmean`` returns it).  The condition number comes from the
    Gram's eigenspectrum — an eigh per tap, paid once at the END of
    calibration, never per batch.
    """
    a = np.asarray(absmean, np.float64)
    n = int(a.shape[0])
    mean = float(a.mean()) if n else 0.0
    stats: Dict = {
        "channels": n,
        "samples": float(count),
        "absmean_mean": mean,
        "absmean_p50": float(np.percentile(a, 50)) if n else 0.0,
        "absmean_p99": float(np.percentile(a, 99)) if n else 0.0,
        "absmean_max": float(a.max()) if n else 0.0,
        "outlier_frac": {},
    }
    for t in thresholds:
        frac = float(np.mean(a > t * mean)) if n and mean > 0 else 0.0
        stats["outlier_frac"][float(t)] = frac
    g = np.asarray(gram, np.float64)
    g = 0.5 * (g + g.T)
    lam = np.linalg.eigvalsh(g)
    lam_max = float(lam[-1]) if lam.size else 0.0
    lam_min = float(np.min(lam[lam > 0])) if np.any(lam > 0) else 0.0
    stats["gram_cond"] = (lam_max / lam_min) if lam_min > 0 else float("inf")
    stats["gram_rank_frac"] = (
        float(np.mean(lam > lam_max * 1e-10)) if lam_max > 0 else 0.0
    )
    return stats


@dataclasses.dataclass
class DecompositionReport:
    """Quality record of one compressed ``TargetSpec`` (all slices).

    Per-slice numbers come from ``core.nsvd.decomposition_diagnostics``;
    scalar fields aggregate across the stacked slices (mean errors, summed
    params).  ``slices`` keeps the raw per-slice dicts so per-LAYER
    attribution survives the aggregation (a stacked (L,) target holds one
    entry per layer)."""

    target: str
    method: str
    shape: Tuple[int, int]  # (out, in) — paper orientation
    stacked: Tuple[int, ...]
    rank: int
    requested_rank: int
    k1: int
    k2: int
    requested_ratio: float
    achieved_ratio: float
    dense_params: int
    factored_params: int
    plain_rel_err: float
    whitened_rel_err: float
    sv_tail_mass: float
    outlier_absorption: float
    gram_fallback_slices: int
    seconds: float
    slices: List[Dict] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["stacked"] = list(self.stacked)
        return d


def _nan_mean(vals: Sequence[float]) -> float:
    xs = [v for v in vals if not math.isnan(v)]
    return float(np.mean(xs)) if xs else float("nan")


class CompressionTelemetry:
    """Facade the calibration runner and the compression orchestrator talk
    to.  Shares the serving registry's metric model, so a serve process
    that compresses at startup exposes compression families on the same
    ``--metrics-port`` endpoint.

    ``compare_plain`` gates the one extra rank-matched plain SVD per slice
    that the outlier-absorption ratio needs; everything else is computed
    from byproducts of the decomposition itself."""

    enabled = True

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 outlier_thresholds: Sequence[float] = OUTLIER_THRESHOLDS,
                 compare_plain: bool = True):
        self.metrics = m = registry if registry is not None else MetricsRegistry()
        self.outlier_thresholds = tuple(outlier_thresholds)
        self.compare_plain = compare_plain
        self.calib: Dict[str, Dict] = {}  # tap -> gram_activation_stats
        self.reports: Dict[str, DecompositionReport] = {}
        self._slices: Dict[str, List[Dict]] = {}

        # -- calibration families
        self.calib_batches = m.counter(
            "compress_calib_batches_total", "calibration batches folded "
            "into the GramStore")
        self.calib_rows = m.counter(
            "compress_calib_rows_total", "activation rows accumulated per "
            "tap", labelnames=("tap",))
        self.calib_samples = m.gauge(
            "compress_calib_samples", "accumulated sample count per Gram "
            "key at the end of calibration", labelnames=("tap",))
        self.calib_outlier_frac = m.gauge(
            "compress_calib_outlier_channel_frac", "fraction of channels "
            "whose mean |activation| exceeds threshold x the tap mean",
            labelnames=("tap", "threshold"))
        self.calib_absmean = m.gauge(
            "compress_calib_absmean", "per-tap absmean channel "
            "distribution", labelnames=("tap", "stat"))
        self.calib_gram_cond = m.gauge(
            "compress_calib_gram_condition_number", "condition number of "
            "the accumulated calibration Gram", labelnames=("tap",))
        self.gram_fallbacks = m.counter(
            "compress_gram_fallbacks_total", "per-slice Gram lookups that "
            "fell back to the shared key (min_count or missing)",
            labelnames=("reason",))

        # -- decomposition families
        self.targets_total = m.counter(
            "compress_targets_total", "TargetSpecs compressed")
        self.slices_total = m.counter(
            "compress_slices_total", "stacked slices factorized")
        self.plain_err = m.gauge(
            "compress_plain_rel_err", "||A - A~||_F / ||A||_F per target "
            "(mean over slices)", labelnames=("target",))
        self.whitened_err = m.gauge(
            "compress_whitened_rel_err", "||(A - A~)X||_F / ||A X||_F per "
            "target (mean over slices)", labelnames=("target",))
        self.tail_mass = m.gauge(
            "compress_sv_tail_mass", "singular-value tail mass at the "
            "chosen rank (whitened energy fraction truncated)",
            labelnames=("target",))
        self.absorption = m.gauge(
            "compress_outlier_absorption", "activation-weighted error "
            "removed by whitening vs a rank-matched plain SVD",
            labelnames=("target",))
        self.rank_achieved = m.gauge(
            "compress_rank_achieved", "rank actually assigned",
            labelnames=("target",))
        self.rank_requested = m.gauge(
            "compress_rank_requested", "unaligned budget rank for the "
            "requested ratio", labelnames=("target",))
        self.factored_params_g = m.gauge(
            "compress_factored_params", "params stored by the "
            "factorization", labelnames=("target",))
        self.seconds = m.histogram(
            "compress_target_seconds", "wall time factorizing one target",
            buckets=(0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0))
        self.k2_share = m.histogram(
            "compress_k2_rank_share", "k2 / (k1 + k2) across targets",
            buckets=(0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0))
        self.slice_whitened_hist = m.histogram(
            "compress_slice_whitened_rel_err", "whitened relative error "
            "across ALL slices", buckets=ERROR_BUCKETS)

    # ---------------------------------------------------- calibration hooks

    def on_calib_batch(self, tap_rows: Dict[str, int]) -> None:
        """One ``accumulate_taps`` call: rows folded per (normalized) tap."""
        self.calib_batches.inc()
        for tap, rows in tap_rows.items():
            self.calib_rows.labels(tap=tap).inc(rows)

    def on_calib_store(self, store) -> None:
        """End-of-calibration sweep over the accumulated GramStore: the
        expensive per-tap statistics (outlier fractions, Gram condition
        numbers) computed exactly once."""
        for key in sorted(store.keys()):
            stats = gram_activation_stats(
                store.gram(key), store.absmean(key), store.count(key),
                thresholds=self.outlier_thresholds)
            self.calib[key] = stats
            self.calib_samples.labels(tap=key).set(stats["samples"])
            for t, frac in stats["outlier_frac"].items():
                self.calib_outlier_frac.labels(
                    tap=key, threshold=repr(t)).set(frac)
            for stat in ("mean", "p50", "p99", "max"):
                self.calib_absmean.labels(tap=key, stat=stat).set(
                    stats[f"absmean_{stat}"])
            cond = stats["gram_cond"]
            self.calib_gram_cond.labels(tap=key).set(
                cond if math.isfinite(cond) else -1.0)

    def on_gram_fallback(self, key: str, fallback: str, reason: str) -> None:
        self.gram_fallbacks.labels(reason=reason).inc()

    # --------------------------------------------------- decomposition hooks

    def on_slice(self, target: str, slice_idx: Tuple[int, ...],
                 diag: Dict) -> None:
        """One factorized matrix (one stacked slice, or the whole kernel
        for unstacked targets).  ``diag`` comes from
        ``core.nsvd.decomposition_diagnostics``."""
        self.slices_total.inc()
        d = dict(diag, slice=list(slice_idx))
        self._slices.setdefault(target, []).append(d)
        if not math.isnan(d.get("whitened_rel_err", float("nan"))):
            self.slice_whitened_hist.observe(d["whitened_rel_err"])

    def on_target(self, *, name: str, method: str, shape: Tuple[int, int],
                  stacked: Tuple[int, ...], rank: int, requested_rank: int,
                  requested_ratio: float, achieved_ratio: float,
                  dense_params: int, factored_params: int,
                  gram_fallback_slices: int, seconds: float) -> DecompositionReport:
        """Aggregate the slices recorded for ``name`` into a report."""
        slices = self._slices.pop(name, [])
        k1 = int(slices[0]["k1"]) if slices else rank
        k2 = int(slices[0]["k2"]) if slices else 0
        report = DecompositionReport(
            target=name, method=method, shape=tuple(shape),
            stacked=tuple(stacked), rank=int(rank),
            requested_rank=int(requested_rank), k1=k1, k2=k2,
            requested_ratio=float(requested_ratio),
            achieved_ratio=float(achieved_ratio),
            dense_params=int(dense_params),
            factored_params=int(factored_params),
            plain_rel_err=_nan_mean([s["plain_rel_err"] for s in slices]),
            whitened_rel_err=_nan_mean(
                [s["whitened_rel_err"] for s in slices]),
            sv_tail_mass=_nan_mean([s["sv_tail_mass"] for s in slices]),
            outlier_absorption=_nan_mean(
                [s["outlier_absorption"] for s in slices]),
            gram_fallback_slices=int(gram_fallback_slices),
            seconds=float(seconds), slices=slices,
        )
        self.reports[name] = report
        self.targets_total.inc()
        self.seconds.observe(seconds)
        if rank > 0:
            self.k2_share.observe(k2 / max(1, k1 + k2))
        for gauge, val in (
            (self.plain_err, report.plain_rel_err),
            (self.whitened_err, report.whitened_rel_err),
            (self.tail_mass, report.sv_tail_mass),
            (self.absorption, report.outlier_absorption),
        ):
            if not math.isnan(val):
                gauge.labels(target=name).set(val)
        self.rank_achieved.labels(target=name).set(rank)
        self.rank_requested.labels(target=name).set(requested_rank)
        self.factored_params_g.labels(target=name).set(factored_params)
        return report

    # ------------------------------------------------------------- export

    def plan_report(self, plan=None) -> Dict:
        """The plan-level JSON artifact: every target's report plus totals
        (and the plan's own achieved-vs-requested summary when given)."""
        targets = [self.reports[k].to_dict() for k in sorted(self.reports)]
        dense = sum(t["dense_params"] for t in targets)
        factored = sum(t["factored_params"] for t in targets)
        doc: Dict = {
            "schema": 1,
            "generated_by": "repro.obs.compression",
            "targets": targets,
            "totals": {
                "targets": len(targets),
                "dense_params": dense,
                "factored_params": factored,
                "achieved_ratio": 1.0 - factored / dense if dense else 0.0,
                "plain_rel_err_mean": _nan_mean(
                    [t["plain_rel_err"] for t in targets]),
                "whitened_rel_err_mean": _nan_mean(
                    [t["whitened_rel_err"] for t in targets]),
                "outlier_absorption_mean": _nan_mean(
                    [t["outlier_absorption"] for t in targets]),
                "gram_fallback_slices": sum(
                    t["gram_fallback_slices"] for t in targets),
            },
            "calibration": self.calib,
        }
        if plan is not None:
            doc["plan"] = {
                "method": plan.config.method,
                "ratio": plan.config.ratio,
                "k1_frac": plan.config.k1_frac,
                "achieved_ratio": plan.achieved_ratio,
                "ranks": dict(plan.ranks),
            }
        return doc

    def write_report(self, path: str, plan=None) -> Dict:
        doc = self.plan_report(plan)
        with open(path, "w") as f:
            json.dump(_json_safe(doc), f, indent=1)
        return doc


def _json_safe(obj):
    """NaN/inf-safe JSON tree (artifacts load everywhere, not just json
    with allow_nan)."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, (np.floating, np.integer)):
        return _json_safe(obj.item())
    return obj


class _NullCompressionTelemetry:
    """Shared no-op twin (the default when no telemetry is supplied)."""

    enabled = False
    __slots__ = ()

    def on_calib_batch(self, tap_rows):
        pass

    def on_calib_store(self, store):
        pass

    def on_gram_fallback(self, key, fallback, reason):
        pass

    def on_slice(self, target, slice_idx, diag):
        pass

    def on_target(self, **kw):
        return None


NULL_COMPRESSION_TELEMETRY = _NullCompressionTelemetry()

# Keep COUNT_BUCKETS imported name alive for callers composing ladders.
__all__ = [
    "CompressionTelemetry", "DecompositionReport",
    "NULL_COMPRESSION_TELEMETRY", "gram_activation_stats",
    "OUTLIER_THRESHOLDS", "ERROR_BUCKETS", "COUNT_BUCKETS",
]
