"""Quality-drift report: dense vs compressed on the eval suite.

    PYTHONPATH=src:. python -m repro.obs.quality_report \
        [--model small-llama --method nsvd1 --ratio 0.2 ...]

One run of this CLI produces the compression-quality counterpart of
``benchmarks.serving_throughput``:

  * trains/loads the small LM (via ``benchmarks.common``), collects
    calibration Grams WITH ``CompressionTelemetry`` attached, compresses,
    and evaluates dense vs compressed perplexity on every eval domain;
  * measures mean per-token logit KL (dense || compressed) and, per
    compressed target, the KL of a params tree that is dense everywhere
    except that one target — the per-layer attribution of the drift;
  * records cross-domain activation similarity (the paper's Table 2
    signal) for the calibration domain vs the most-shifted eval domain;
  * APPENDS a git-SHA + config-hash stamped entry to the append-only
    ``BENCH_quality.json`` history at the repo root (never clobbered),
    which ``benchmarks/sentinel.py`` diffs against prior entries at the
    same config hash;
  * optionally (--report) writes the full per-target decomposition
    diagnostics artifact from the telemetry layer.

The telemetry is a pure observer: the compressed params this CLI
evaluates are bit-identical to a run with reporting off.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import time
from typing import Dict, List, Optional

BENCH_QUALITY_SCHEMA = 1
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_HISTORY = os.path.join(_REPO_ROOT, "BENCH_quality.json")


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=_REPO_ROOT,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def config_hash(meta: Dict) -> str:
    blob = json.dumps(meta, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def append_quality_history(entry: Dict, path: str = DEFAULT_HISTORY) -> Dict:
    """Append a stamped entry to the quality history (append-only: prior
    entries are preserved verbatim) and return the written document."""
    history: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("history"), list):
                history = prev["history"]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    doc = {
        "schema": BENCH_QUALITY_SCHEMA,
        "generated_by": "repro.obs.quality_report",
        "history": history,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def build_entry(
    model_name: str = "small-llama",
    method: str = "nsvd1",
    ratio: float = 0.2,
    k1_frac: float = 0.9,
    eval_n_batches: int = 6,
    calib_samples: int = 256,
    attribution: bool = True,
    attribution_batches: int = 2,
    report_path: Optional[str] = None,
) -> Dict:
    """Run the full quality pipeline and return the history entry."""
    # The trained-model/bench harness lives outside the package; run with
    # PYTHONPATH=src:. from the repo root (the error below says so).
    try:
        from benchmarks.common import SEQ, VOCAB, EVAL_DOMAINS, train_small_lm
    except ImportError as e:
        raise ImportError(
            "repro.obs.quality_report needs the benchmarks harness on the "
            "path: run from the repo root with PYTHONPATH=src:. ") from e

    from repro.calib.runner import calibration_batches, collect_grams
    from repro.core import CompressionConfig, build_plan, compress_params
    from repro.eval.attribution import mean_logit_kl, per_target_attribution
    from repro.eval.perplexity import (
        activation_similarity,
        eval_batches,
        evaluate_ppl,
    )
    from repro.obs.compression import CompressionTelemetry

    t0 = time.time()
    model, params, _ = train_small_lm(model_name)

    telemetry = CompressionTelemetry()
    print(f"  [{model_name}] calibrating ({calib_samples} samples)...")
    grams = collect_grams(
        model, params,
        calibration_batches(VOCAB, "en_a", n_samples=calib_samples,
                            batch=16, seq=SEQ),
        telemetry=telemetry,
    )

    cfg = CompressionConfig(method=method, ratio=ratio, k1_frac=k1_frac,
                            dtype="float32", use_randomized=False)
    plan = build_plan(model.compressible_targets(), cfg)
    print(f"  [{model_name}] compressing "
          f"({method} ratio={ratio} k1_frac={k1_frac})...")
    cparams = compress_params(params, plan, grams, telemetry=telemetry)

    dense_ppl: Dict[str, float] = {}
    compressed_ppl: Dict[str, float] = {}
    for d in EVAL_DOMAINS:
        dense_ppl[d] = evaluate_ppl(
            model, params,
            eval_batches(VOCAB, d, n_batches=eval_n_batches, batch=16, seq=SEQ))
        compressed_ppl[d] = evaluate_ppl(
            model, cparams,
            eval_batches(VOCAB, d, n_batches=eval_n_batches, batch=16, seq=SEQ))
        print(f"  ppl[{d}]: dense={dense_ppl[d]:.2f} "
              f"compressed={compressed_ppl[d]:.2f} "
              f"(x{compressed_ppl[d] / dense_ppl[d]:.3f})")

    logit_kl = mean_logit_kl(
        model, params, cparams,
        eval_batches(VOCAB, "en_a", n_batches=eval_n_batches, batch=16, seq=SEQ))
    print(f"  logit KL (dense || compressed): {logit_kl:.5f} nats/token")

    attribution_rows: List[Dict] = []
    if attribution:
        attribution_rows = per_target_attribution(
            model, params, cparams, plan.targets,
            lambda: eval_batches(VOCAB, "en_a", n_batches=attribution_batches,
                                 batch=16, seq=SEQ))
        for r in attribution_rows[:3]:
            print(f"  attribution: {r['target']} "
                  f"kl={r['logit_kl']:.5f} share={r['share']:.0%}")

    # Cross-domain activation shift: calibration domain vs the most
    # distribution-shifted eval domain (zh) — the mechanism behind
    # domain-dependent quality drift.
    sims = activation_similarity(model, params, "en_a", "zh", VOCAB)
    sim_vals = list(sims.values())
    act_sim = {
        "domains": ["en_a", "zh"],
        "mean": sum(sim_vals) / max(len(sim_vals), 1),
        "min": min(sim_vals) if sim_vals else 0.0,
    }

    if report_path:
        telemetry.write_report(report_path, plan=plan)
        print(f"  decomposition report -> {report_path}")

    plan_doc = telemetry.plan_report(plan=plan)
    meta = {"model": model_name, "method": method, "ratio": ratio,
            "k1_frac": k1_frac, "eval_n_batches": eval_n_batches,
            "calib_samples": calib_samples}
    entry = {
        "git_sha": git_sha(),
        "config_hash": config_hash(meta),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "meta": meta,
        "achieved_ratio": plan.achieved_ratio,
        "dense_ppl": dense_ppl,
        "compressed_ppl": compressed_ppl,
        "ppl_ratio": {d: compressed_ppl[d] / dense_ppl[d]
                      for d in compressed_ppl},
        "logit_kl": logit_kl,
        "attribution": attribution_rows,
        "activation_similarity": act_sim,
        "decomposition": plan_doc["totals"],
        "wall_s": time.time() - t0,
    }
    return entry


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="dense-vs-compressed quality report "
                    "(appends to BENCH_quality.json)")
    ap.add_argument("--model", default="small-llama")
    ap.add_argument("--method", default="nsvd1")
    ap.add_argument("--ratio", type=float, default=0.2)
    ap.add_argument("--k1-frac", type=float, default=0.9)
    ap.add_argument("--eval-batches", type=int, default=6)
    ap.add_argument("--calib-samples", type=int, default=256)
    ap.add_argument("--attribution-batches", type=int, default=2)
    ap.add_argument("--no-attribution", action="store_true",
                    help="skip the per-target logit-KL patching pass")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the per-target decomposition "
                         "diagnostics JSON artifact")
    ap.add_argument("--history", default=DEFAULT_HISTORY, metavar="PATH",
                    help="BENCH_quality.json path (append-only)")
    args = ap.parse_args(argv)

    entry = build_entry(
        model_name=args.model, method=args.method, ratio=args.ratio,
        k1_frac=args.k1_frac, eval_n_batches=args.eval_batches,
        calib_samples=args.calib_samples,
        attribution=not args.no_attribution,
        attribution_batches=args.attribution_batches,
        report_path=args.report,
    )
    doc = append_quality_history(entry, args.history)
    print(f"  quality entry -> {args.history} "
          f"[{entry['git_sha']} {entry['config_hash']}, "
          f"{len(doc['history'])} run(s)]")


if __name__ == "__main__":
    main()
