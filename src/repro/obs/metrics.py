"""Dependency-free metrics registry for the serving observability layer.

Counters, gauges and histograms populated from HOST-side bookkeeping only
(the engine's packed D2H word plus its own scheduling state — never an
extra device sync), with two export surfaces:

  * ``MetricsRegistry.snapshot()`` — a plain-JSON dict (the file-based
    scrape ``launch/serve.py --metrics-json`` writes, and the block the
    bench stamps into BENCH_serving.json).
  * ``MetricsRegistry.prometheus_text()`` — Prometheus text exposition
    (served by ``MetricsServer`` for ``--metrics-port``).

Every metric is a FAMILY keyed by label values (an unlabeled metric is a
family with the single empty-label child), mirroring the Prometheus data
model without the client library.  Histograms keep fixed cumulative
buckets for the exposition format plus a bounded window of raw samples for
exact p50/p99 in snapshots — the window is what the TTFT/TPOT percentile
claims in the bench history are computed from, so its size bounds staleness,
not correctness of the counts."""

from __future__ import annotations

import bisect
import json
import threading
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

# Default bucket ladders (seconds / counts).  Powers-of-~3 keep the ladder
# short while spanning CPU-emulation steps (ms) and real accelerator steps
# (tens of us).
TIME_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0)
COUNT_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128)
FRACTION_BUCKETS = (0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0)


class Counter:
    """Monotonic counter (one labeled child of a family)."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> Dict:
        return {"value": self.value}


class Gauge:
    """Point-in-time value (set wins; inc/dec for running levels)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> Dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram + bounded raw-sample window.

    ``counts[i]`` is the number of observations <= ``buckets[i]`` minus the
    ones in lower buckets (non-cumulative internally; the exposition
    cumulates), with one overflow bucket.  ``percentile`` is exact over the
    last ``window`` observations."""

    kind = "histogram"
    __slots__ = ("buckets", "counts", "sum", "count", "max", "_window")

    def __init__(self, buckets: Sequence[float] = TIME_BUCKETS,
                 window: int = 4096):
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self.max = 0.0
        self._window: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        if v > self.max:
            self.max = v
        self._window.append(v)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Exact q-th percentile over the retained window (0 when empty)."""
        if not self._window:
            return 0.0
        xs = sorted(self._window)
        idx = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def snapshot(self) -> Dict:
        cum, out = 0, {}
        for b, c in zip(self.buckets, self.counts):
            cum += c
            out[repr(float(b))] = cum
        return {
            "count": self.count, "sum": self.sum, "mean": self.mean(),
            "max": self.max, "p50": self.percentile(50),
            "p90": self.percentile(90), "p99": self.percentile(99),
            "buckets": out,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One named metric family: children keyed by label-value tuples."""

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Tuple[str, ...] = (), **child_kw):
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self._child_kw = child_kw
        self._children: Dict[Tuple[str, ...], object] = {}
        if not self.labelnames:  # unlabeled: materialize the sole child
            self._default = self.labels()
        else:
            self._default = None

    def labels(self, *values, **kv):
        if kv:
            values = tuple(str(kv[n]) for n in self.labelnames)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {values}")
        child = self._children.get(values)
        if child is None:
            child = self._children[values] = _KINDS[self.kind](
                **self._child_kw)
        return child

    # Unlabeled families proxy the child's mutators so call sites read
    # like plain metrics (family.inc(), family.observe(v), ...).
    def inc(self, n: float = 1.0):
        self._default.inc(n)

    def set(self, v: float):
        self._default.set(v)

    def observe(self, v: float):
        self._default.observe(v)

    def mean(self) -> float:
        return self._default.mean()

    def percentile(self, q: float) -> float:
        return self._default.percentile(q)

    def snapshot(self) -> Dict:
        return self._default.snapshot()

    @property
    def value(self):
        return self._default.value

    @property
    def count(self):
        return self._default.count

    @property
    def max(self):
        return self._default.max

    def series(self):
        for values, child in sorted(self._children.items()):
            yield dict(zip(self.labelnames, values)), child


class MetricsRegistry:
    """Named families; snapshot + Prometheus text exposition."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}

    def _register(self, name: str, help: str, kind: str, labelnames=(),
                  **kw) -> _Family:
        if name in self._families:
            fam = self._families[name]
            if fam.kind != kind or fam.labelnames != tuple(labelnames):
                raise ValueError(f"metric {name!r} re-registered with a "
                                 "different kind/labels")
            return fam
        fam = _Family(name, help, kind, labelnames, **kw)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "", labelnames=()) -> _Family:
        return self._register(name, help, "counter", labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> _Family:
        return self._register(name, help, "gauge", labelnames)

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets: Sequence[float] = TIME_BUCKETS,
                  window: int = 4096) -> _Family:
        return self._register(name, help, "histogram", labelnames,
                              buckets=buckets, window=window)

    def snapshot(self) -> Dict:
        out: Dict[str, Dict] = {}
        for name, fam in sorted(self._families.items()):
            out[name] = {
                "type": fam.kind,
                "help": fam.help,
                "series": [dict(labels=labels, **child.snapshot())
                           for labels, child in fam.series()],
            }
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels, child in fam.series():
                if fam.kind == "histogram":
                    cum = 0
                    for b, c in zip(child.buckets, child.counts):
                        cum += c
                        lines.append(
                            f"{name}_bucket{_labels(labels, le=_fmt(b))}"
                            f" {cum}")
                    lines.append(
                        f"{name}_bucket{_labels(labels, le='+Inf')}"
                        f" {child.count}")
                    lines.append(f"{name}_sum{_labels(labels)}"
                                 f" {_fmt(child.sum)}")
                    lines.append(f"{name}_count{_labels(labels)}"
                                 f" {child.count}")
                else:
                    lines.append(f"{name}{_labels(labels)}"
                                 f" {_fmt(child.value)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _labels(labels: Dict[str, str], **extra) -> str:
    merged = dict(labels, **extra)
    if not merged:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in merged.items())
    return "{" + body + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


class MetricsServer:
    """Minimal scrape endpoint: ``GET /metrics`` serves the Prometheus
    text exposition, ``GET /metrics.json`` the snapshot dict, and
    ``GET /healthz`` a readiness probe.  Runs on a daemon thread;
    ``port=0`` binds an ephemeral port (``.port`` reports the bound one).

    ``health`` is an optional zero-arg callable naming currently-degraded
    components (e.g. ``engine.degraded_components``): when it returns a
    non-empty dict, /healthz answers 503 with a JSON body instead of a
    bare 200 "ok", so orchestrators see draft-off / stalled-slot /
    draining states rather than a false all-clear."""

    def __init__(self, source, port: int = 0, host: str = "127.0.0.1",
                 health=None):
        import http.server

        snapshot = getattr(source, "snapshot")
        prometheus = getattr(source, "prometheus_text")

        class _Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                code = 200
                if self.path.startswith("/metrics.json"):
                    body = json.dumps(snapshot()).encode()
                    ctype = "application/json"
                elif self.path.startswith("/metrics"):
                    body = prometheus().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.startswith("/healthz"):
                    degraded = health() if health is not None else {}
                    if degraded:
                        code = 503
                        body = json.dumps({"status": "degraded",
                                           "components": degraded}).encode()
                        ctype = "application/json"
                    else:
                        body = b"ok\n"
                        ctype = "text/plain; charset=utf-8"
                else:
                    self.send_error(404)
                    return
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # keep the serving stdout clean
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), _Handler)
        self.port = int(self._httpd.server_address[1])
        self.host = host
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-metrics", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


def write_metrics_json(source, path: str,
                       extra: Optional[Dict] = None) -> None:
    """File-based scrape: dump a snapshot (plus any engine-side extras)
    atomically enough for a poller (write + rename)."""
    import os
    import tempfile

    doc = {"metrics": source.snapshot()}
    if extra:
        doc.update(extra)
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise
