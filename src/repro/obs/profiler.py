"""jax.profiler hooks for the serving hot path.

Two instrumentation layers, split by where they run:

  * ``wrap_root(fn, name)`` — wraps a serving root's step function in a
    ``jax.named_scope`` so every op the root lowers carries the root's
    name in profiler timelines and HLO dumps.  named_scope is pure
    metadata (it annotates the jaxpr, it emits no ops), so the wrapped
    root lowers to the same computation — the static contract auditor
    traces the WRAPPED builds (launch/steps.serving_root_registry wraps at
    the registry, the auditor's single source of truth), which is the
    proof the instrumentation adds zero transfers.  Applied
    unconditionally: there is no on/off divergence to perturb tokens.

  * ``annotation(name)`` — a host-side ``jax.profiler.TraceAnnotation``
    span for dispatch/sync regions of the ENGINE loop (outside jit).
    These only mark time on the host timeline while a profiler trace is
    being captured; they never touch the computation.

``ProfileCapture`` drives ``jax.profiler.start_trace/stop_trace`` from the
engine's step hooks: capture begins at the first dispatched step and ends
after N steps have been consumed (so the captured window holds N complete
dispatch->sync step cycles), degrading to a no-op if the backend's
profiler is unavailable."""

from __future__ import annotations

import contextlib
import functools

import jax

_NULL = contextlib.nullcontext()


def wrap_root(fn, name: str):
    """Name a serving root's trace (``serving_root.<name>`` scope).

    The marker attribute ``__obs_name__`` lets the auditor CLI verify the
    registry hands out instrumented builds (``--require-instrumented``)."""

    @functools.wraps(fn)
    def wrapped(*args):
        with jax.named_scope(f"serving_root.{name}"):
            return fn(*args)

    wrapped.__obs_name__ = name
    return wrapped


def annotation(name: str):
    """Host-side profiler span (nullcontext if TraceAnnotation is absent)."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return _NULL


class ProfileCapture:
    """Capture a ``jax.profiler`` trace of N engine steps into a directory
    (viewable with TensorBoard's profile plugin / Perfetto).

    The engine calls ``tick_dispatch()`` before each root dispatch and
    ``tick_consume()`` after each consumed step; the capture starts on the
    first dispatch and stops once ``n_steps`` steps have been consumed.
    Failures (no profiler backend, double-start) disable the capture
    rather than sinking the serving loop."""

    def __init__(self, profile_dir: str, n_steps: int = 8):
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        self.profile_dir = profile_dir
        self.n_steps = n_steps
        self.started = False
        self.finished = False
        self._consumed = 0

    def tick_dispatch(self) -> None:
        if self.started or self.finished:
            return
        try:
            jax.profiler.start_trace(self.profile_dir)
            self.started = True
        except Exception:
            self.finished = True  # profiler unavailable: never retry

    def tick_consume(self) -> None:
        if not self.started or self.finished:
            return
        self._consumed += 1
        if self._consumed >= self.n_steps:
            self.stop()

    def stop(self) -> None:
        if self.started and not self.finished:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
        self.finished = True
