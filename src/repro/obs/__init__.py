"""Serving observability: structured event tracing, a dependency-free
metrics registry, and jax.profiler hooks — all fed from HOST-side
bookkeeping the engine already does (the packed D2H word + scheduling
state), never an extra device sync.  The static contract auditor
(repro.analysis) traces the instrumented roots, so "telemetry adds zero
transfers" is a checked property, not a convention.

Usage:

    from repro.obs import Telemetry
    tel = Telemetry()
    eng = ServingEngine(model, params, telemetry=tel)
    eng.run()
    tel.snapshot(eng)        # JSON metrics + engine gauges
    tel.metrics.prometheus_text()
    tel.tracer.export_chrome("trace.json")

``ServingEngine(...)`` without ``telemetry=`` gets ``NULL_TELEMETRY`` — a
shared no-op whose ``enabled`` flag gates every per-row/per-step hook in
the engine, so the disabled hot path does no tracing work at all (pinned
by tests/test_observability.py)."""

from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

from repro.obs.compression import (
    NULL_COMPRESSION_TELEMETRY,
    CompressionTelemetry,
    DecompositionReport,
    gram_activation_stats,
)
from repro.obs.metrics import (
    COUNT_BUCKETS,
    FRACTION_BUCKETS,
    TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MetricsServer,
    write_metrics_json,
)
from repro.obs.profiler import ProfileCapture, annotation, wrap_root
from repro.obs.trace import PID_ENGINE, PID_REQUESTS, EventTracer

__all__ = [
    "Telemetry", "NULL_TELEMETRY", "disabled",
    "CompressionTelemetry", "DecompositionReport",
    "NULL_COMPRESSION_TELEMETRY", "gram_activation_stats",
    "EventTracer", "MetricsRegistry", "MetricsServer",
    "Counter", "Gauge", "Histogram", "ProfileCapture",
    "annotation", "wrap_root", "write_metrics_json",
    "TIME_BUCKETS", "COUNT_BUCKETS", "FRACTION_BUCKETS",
]

_NULLCTX = contextlib.nullcontext()


class Telemetry:
    """Facade the engine talks to: one tracer + one metrics registry +
    optional N-step profiler capture.  Every ``on_*`` hook is host-only
    and O(its arguments); the engine guards per-row work behind
    ``telemetry.enabled`` so the disabled path stays no-op."""

    enabled = True

    def __init__(self, trace_capacity: int = 65536, window: int = 4096,
                 profile_dir: Optional[str] = None, profile_steps: int = 8,
                 spec_meta: Optional[Dict] = None):
        self.tracer = EventTracer(trace_capacity)
        self.metrics = m = MetricsRegistry()
        self.spec_meta = dict(spec_meta or {})
        self.profile = (ProfileCapture(profile_dir, profile_steps)
                        if profile_dir else None)

        # -- request lifecycle
        self.requests_submitted = m.counter(
            "serving_requests_submitted_total", "requests entering the queue")
        self.requests_finished = m.counter(
            "serving_requests_finished_total", "requests fully generated")
        self.tokens_emitted = m.counter(
            "serving_tokens_emitted_total", "tokens committed to requests")
        self.queue_wait = m.histogram(
            "serving_queue_wait_seconds", "submit -> admission wait",
            buckets=TIME_BUCKETS, window=window)
        self.ttft = m.histogram(
            "serving_ttft_seconds", "submit -> first token",
            buckets=TIME_BUCKETS, window=window)
        self.tpot = m.histogram(
            "serving_tpot_seconds", "per-token latency after the first "
            "(time-per-output-token)", buckets=TIME_BUCKETS, window=window)
        self.preempt_ready = m.counter(
            "serving_preempt_ready_total", "rows flagged preemptible "
            "(fired for the victim the scheduler actually evicts, and for "
            "the most-blocks row when admission is backpressured)")

        # -- scheduler (serving/scheduler): growth, preemption, occupancy
        self.preempts = m.counter(
            "serving_preempt_total", "rows preempted (victim evicted)",
            labelnames=("reason",))
        self.swap_bytes = m.counter(
            "serving_swap_bytes_total", "KV bytes swapped to host by "
            "preemptions (resume='swap' only)")
        self.pool_reserved_vs_live = m.gauge(
            "serving_pool_reserved_vs_live_frac", "live committed tokens / "
            "reserved pool tokens at dispatch (on-demand admission drives "
            "this toward 1; worst-case reservation leaves it low)")

        # -- step machinery
        self.step_dispatch = m.histogram(
            "serving_step_dispatch_seconds", "root dispatch wall time",
            buckets=TIME_BUCKETS, window=window)
        self.step_sync = m.histogram(
            "serving_step_sync_seconds", "D2H ring-sync stall per consumed "
            "step", buckets=TIME_BUCKETS, window=window)
        self.step_host = m.histogram(
            "serving_step_host_seconds", "host emission/free bookkeeping "
            "per consumed step", buckets=TIME_BUCKETS, window=window)
        self.ring_depth = m.histogram(
            "serving_ring_depth", "in-flight steps at dispatch",
            buckets=COUNT_BUCKETS, window=window)
        self.batch_occupancy = m.histogram(
            "serving_batch_occupancy_rows", "live rows per dispatched step",
            buckets=COUNT_BUCKETS, window=window)
        self.drains = m.counter(
            "serving_ring_drain_total", "pipeline drains (admission, "
            "defrag, dynamic-k, tail flush)")
        self.steps_dispatched = m.counter(
            "serving_steps_dispatched_total", "decode/spec root dispatches")

        # -- paged block pool (per DP shard)
        self.pool_in_use = m.gauge(
            "serving_pool_blocks_in_use", "live blocks per DP shard",
            labelnames=("shard",))
        self.pool_peak = m.gauge(
            "serving_pool_blocks_peak", "peak live blocks per DP shard",
            labelnames=("shard",))
        self.pool_occupancy = m.histogram(
            "serving_pool_occupancy_frac", "pool fraction in use at "
            "dispatch (max over shards)", buckets=FRACTION_BUCKETS,
            window=window)
        self.defrags = m.counter(
            "serving_defrag_total", "defrag compactions")
        self.defrag_moves = m.counter(
            "serving_defrag_moved_blocks_total", "blocks moved by defrag")
        self.rollbacks = m.counter(
            "serving_rollback_total", "cache length rollbacks "
            "(allocator suffix releases)")

        # -- speculation: outcomes per (window, accepted) and the
        #    acceptance histogram keyed by (k, draft-ratio)
        self.spec_rows = m.counter(
            "serving_spec_rows_total", "speculative row-steps by window "
            "and accepted draft tokens", labelnames=("k", "accepted"))
        self.spec_accepted_hist = m.histogram(
            "serving_spec_accepted_tokens", "accepted draft tokens per "
            "row-step", labelnames=("k", "draft_ratio"),
            buckets=COUNT_BUCKETS, window=window)
        self.spec_committed = m.counter(
            "serving_spec_committed_tokens_total", "tokens committed by "
            "speculative steps (accepted + correction/bonus)")

        # -- fault tolerance (serving/faults): detections, retries,
        #    shedding, degraded components, straggler verdicts
        self.faults = m.counter(
            "serving_faults_total", "faults detected/injected by kind "
            "(poison_logits, alloc_fail, swap_corrupt, straggler, "
            "draft_kill)", labelnames=("kind",))
        self.retries = m.counter(
            "serving_retries_total", "poisoned requests requeued for a "
            "backed-off reprefill retry instead of retiring with an error")
        self.deadline_shed = m.counter(
            "serving_deadline_shed_total", "queued requests shed because "
            "their deadline expired before admission")
        self.degraded_mode = m.gauge(
            "serving_degraded_mode", "1 while a component runs degraded "
            "(draft: spec decode fell back to plain decode)",
            labelnames=("component",))
        self.straggler_steps = m.counter(
            "serving_straggler_steps_total", "watchdog-flagged step "
            "durations by verdict", labelnames=("verdict",))

    # ----------------------------------------------------- request hooks

    def on_submit(self, uid: int, prompt_len: int, max_new: int) -> None:
        self.requests_submitted.inc()
        self.tracer.instant("submit", "request", PID_REQUESTS, uid,
                            {"prompt_len": prompt_len, "max_new": max_new})

    def on_admit(self, uid: int, slot: int, wait_s: float) -> None:
        self.queue_wait.observe(wait_s)
        self.tracer.instant("admit", "request", PID_REQUESTS, uid,
                            {"slot": slot, "queue_wait_s": wait_s})

    def on_first_chunk(self, uid: int, slot: int) -> None:
        self.tracer.instant("first_chunk", "request", PID_REQUESTS, uid,
                            {"slot": slot})

    def on_first_token(self, uid: int, slot: int, ttft_s: float) -> None:
        self.ttft.observe(ttft_s)
        self.tokens_emitted.inc()
        self.tracer.instant("first_token", "request", PID_REQUESTS, uid,
                            {"slot": slot, "ttft_s": ttft_s})

    def on_commit(self, uid: int, slot: int, n_tokens: int) -> None:
        self.tokens_emitted.inc(n_tokens)
        self.tracer.instant("commit", "request", PID_REQUESTS, uid,
                            {"slot": slot, "tokens": n_tokens})

    def on_finish(self, uid: int, n_generated: int, ttft_s: float,
                  tpot_s: float) -> None:
        self.requests_finished.inc()
        if n_generated > 1:
            self.tpot.observe(tpot_s)
        self.tracer.instant("finish", "request", PID_REQUESTS, uid,
                            {"generated": n_generated, "ttft_s": ttft_s,
                             "tpot_s": tpot_s})

    def on_preempt_ready(self, uid: int, slot: int) -> None:
        """A row the scheduler could (or is about to) evict to relieve
        pool pressure — fired for the most-blocks row when admission is
        backpressured, and for the actual victim right before every
        ``on_preempt``."""
        self.preempt_ready.inc()
        self.tracer.instant("preempt_ready", "request", PID_REQUESTS, uid,
                            {"slot": slot})

    # --------------------------------------------------- scheduler hooks
    # (cat="sched": scheduler lifecycle events are engine policy, not part
    # of the per-request event multiset depth-invariance tests pin.)

    def on_grow(self, uid: int, slot: int, n_blocks: int,
                pool_in_use: int) -> None:
        """On-demand block growth extended a live row's reservation."""
        self.tracer.instant("grow", "sched", PID_ENGINE, 0,
                            {"uid": uid, "slot": slot, "blocks": n_blocks,
                             "pool_in_use": pool_in_use})

    def on_preempt(self, uid: int, slot: int, reason: str, blocks: int,
                   swap_bytes: int) -> None:
        """A live row was evicted (reason: "pool_dry" growth pressure or
        "priority" SLA admission); its blocks are free again."""
        self.preempts.labels(reason=reason).inc()
        if swap_bytes:
            self.swap_bytes.inc(swap_bytes)
        self.tracer.instant("preempt", "sched", PID_REQUESTS, uid,
                            {"slot": slot, "reason": reason,
                             "blocks": blocks, "swap_bytes": swap_bytes})

    def on_resume(self, uid: int, slot: int, mode: str) -> None:
        """A preempted request re-entered a slot (reprefill or swap)."""
        self.tracer.instant("resume", "sched", PID_REQUESTS, uid,
                            {"slot": slot, "mode": mode})

    # -------------------------------------------------------- step hooks

    def on_step_dispatch(self, kind: str, ring_depth: int, live_rows: int,
                         dispatch_s: float,
                         pool_in_use: Optional[List[int]] = None,
                         blocks_per_shard: Optional[int] = None,
                         live_tokens: Optional[int] = None,
                         reserved_tokens: Optional[int] = None) -> None:
        self.steps_dispatched.inc()
        self.step_dispatch.observe(dispatch_s)
        self.ring_depth.observe(ring_depth)
        self.batch_occupancy.observe(live_rows)
        args = {"ring_depth": ring_depth, "live_rows": live_rows}
        if pool_in_use is not None and blocks_per_shard:
            for s, used in enumerate(pool_in_use):
                self.pool_in_use.labels(shard=str(s)).set(used)
            frac = max(pool_in_use) / blocks_per_shard
            self.pool_occupancy.observe(frac)
            args["pool_frac"] = frac
        if live_tokens is not None and reserved_tokens:
            self.pool_reserved_vs_live.set(live_tokens / reserved_tokens)
        self.tracer.complete(f"dispatch:{kind}", "step", dispatch_s,
                             PID_ENGINE, 0, args)
        if self.profile is not None:
            self.profile.tick_dispatch()

    def on_step_consume(self, kind: str, sync_s: float,
                        host_s: float) -> None:
        self.step_sync.observe(sync_s)
        self.step_host.observe(host_s)
        self.tracer.complete(f"sync:{kind}", "step", sync_s, PID_ENGINE, 1)
        self.tracer.complete(f"host:{kind}", "step", host_s, PID_ENGINE, 1)
        if self.profile is not None:
            self.profile.tick_consume()

    def on_drain(self, n_in_flight: int) -> None:
        self.drains.inc()
        self.tracer.instant("drain", "step", PID_ENGINE, 0,
                            {"in_flight": n_in_flight})

    def on_defrag(self, moved: int) -> None:
        self.defrags.inc()
        self.defrag_moves.inc(moved)
        self.tracer.instant("defrag", "step", PID_ENGINE, 0,
                            {"moved": moved})

    def on_spec_row(self, k_eff: int, accepted: int) -> None:
        self.spec_rows.labels(k=str(k_eff), accepted=str(accepted)).inc()
        self.spec_accepted_hist.labels(
            k=str(self.spec_meta.get("k", k_eff)),
            draft_ratio=str(self.spec_meta.get("draft_ratio", "?")),
        ).observe(accepted)

    # ------------------------------------------------------- fault hooks
    # (cat="fault": fired where the fault OCCURS — poison at host
    # detection of the packed sentinel, the injected kinds at their
    # injection sites — so the trace timeline localizes each fault.)

    def on_fault(self, kind: str, uid: Optional[int], step: int) -> None:
        self.faults.labels(kind=kind).inc()
        self.tracer.instant(f"fault:{kind}", "fault", PID_ENGINE, 0,
                            {"uid": uid, "step": step})

    def on_retry(self, uid: int, attempt: int, backoff_steps: int) -> None:
        self.retries.inc()
        self.tracer.instant("fault_retry", "fault", PID_REQUESTS, uid,
                            {"attempt": attempt,
                             "backoff_steps": backoff_steps})

    def on_shed(self, uid: int, reason: str) -> None:
        if reason == "deadline":
            self.deadline_shed.inc()
        self.tracer.instant("shed", "fault", PID_REQUESTS, uid,
                            {"reason": reason})

    def on_degraded(self, component: str, active: bool) -> None:
        self.degraded_mode.labels(component=component).set(int(active))
        self.tracer.instant("degraded", "fault", PID_ENGINE, 0,
                            {"component": component, "active": active})

    def on_straggler(self, verdict: str, dur_s: float) -> None:
        self.straggler_steps.labels(verdict=verdict).inc()
        self.tracer.instant("straggler", "fault", PID_ENGINE, 1,
                            {"verdict": verdict, "dur_s": dur_s})

    def span(self, name: str):
        """Host-side profiler span around a dispatch/sync region."""
        return annotation(name)

    # ------------------------------------------------------------ export

    def snapshot(self, engine=None) -> Dict:
        """JSON metrics snapshot, plus engine-derived gauges (pool
        occupancy/peaks, allocator counters, mesh, spec meta) when an
        engine is supplied — all read from host state."""
        if engine is not None:
            self._scrape_engine(engine)
        out: Dict = {"metrics": self.metrics.snapshot(),
                     "trace": {"events": len(self.tracer),
                               "dropped": self.tracer.dropped}}
        if self.spec_meta:
            out["spec_meta"] = dict(self.spec_meta)
        if engine is not None:
            out["engine"] = {
                "stats": engine.stats(),
                "cache": engine.cache_stats(),
                "spec": engine.spec_stats(),
                "scheduler": engine.scheduler_stats(),
                "faults": engine.fault_stats(),
            }
            if engine.paged:
                out["engine"]["allocator"] = dict(engine.kv.alloc.counters)
        return out

    def _scrape_engine(self, engine) -> None:
        if not engine.paged:
            return
        alloc = engine.kv.alloc
        for s in range(alloc.num_shards):
            self.pool_in_use.labels(shard=str(s)).set(alloc.in_use(s))
            self.pool_peak.labels(shard=str(s)).set(alloc.peak_by_shard[s])
        self.rollbacks.inc(
            alloc.counters["release_suffix_calls"] - self.rollbacks.value)

    def bench_block(self) -> Dict:
        """The BENCH_serving.json schema-6 ``telemetry`` block: TTFT/TPOT
        percentiles, queue wait, occupancy mean/peak, spec win/loss per
        (k, accepted)."""
        def pct(h):
            return {"p50": h.percentile(50), "p99": h.percentile(99),
                    "mean": h.mean(), "count": h.count}

        block: Dict = {
            "ttft_s": pct(self.ttft),
            "tpot_s": pct(self.tpot),
            "queue_wait_s": pct(self.queue_wait),
            "occupancy": {
                "rows_mean": self.batch_occupancy.mean(),
                "rows_peak": self.batch_occupancy.max,
                "pool_frac_mean": self.pool_occupancy.mean(),
                "pool_frac_peak": self.pool_occupancy.max,
            },
            "steps": int(self.steps_dispatched.value),
            "tokens": int(self.tokens_emitted.value),
        }
        outcomes = [
            dict(k=int(labels["k"]), accepted=int(labels["accepted"]),
                 rows=int(child.value))
            for labels, child in self.spec_rows.series()
        ]
        if outcomes:
            total = sum(o["rows"] for o in outcomes)
            accepted = sum(o["accepted"] * o["rows"] for o in outcomes)
            proposed = sum(o["k"] * o["rows"] for o in outcomes)
            block["spec"] = {
                "k": self.spec_meta.get("k"),
                "draft_ratio": self.spec_meta.get("draft_ratio"),
                "outcomes": outcomes,
                "row_steps": total,
                "acceptance_rate": accepted / max(1, proposed),
            }
        else:
            block["spec"] = None
        return block


class _NullTelemetry:
    """Shared no-op: every hook is a pass, ``span`` hands back one reused
    nullcontext.  The engine stores this when no telemetry is supplied and
    additionally guards per-row work behind ``enabled``."""

    enabled = False
    __slots__ = ()

    def span(self, name):
        return _NULLCTX

    def on_submit(self, uid, prompt_len, max_new):
        pass

    def on_admit(self, uid, slot, wait_s):
        pass

    def on_first_chunk(self, uid, slot):
        pass

    def on_first_token(self, uid, slot, ttft_s):
        pass

    def on_commit(self, uid, slot, n_tokens):
        pass

    def on_finish(self, uid, n_generated, ttft_s, tpot_s):
        pass

    def on_preempt_ready(self, uid, slot):
        pass

    def on_grow(self, uid, slot, n_blocks, pool_in_use):
        pass

    def on_preempt(self, uid, slot, reason, blocks, swap_bytes):
        pass

    def on_resume(self, uid, slot, mode):
        pass

    def on_step_dispatch(self, kind, ring_depth, live_rows, dispatch_s,
                         pool_in_use=None, blocks_per_shard=None,
                         live_tokens=None, reserved_tokens=None):
        pass

    def on_step_consume(self, kind, sync_s, host_s):
        pass

    def on_drain(self, n_in_flight):
        pass

    def on_defrag(self, moved):
        pass

    def on_spec_row(self, k_eff, accepted):
        pass

    def on_fault(self, kind, uid, step):
        pass

    def on_retry(self, uid, attempt, backoff_steps):
        pass

    def on_shed(self, uid, reason):
        pass

    def on_degraded(self, component, active):
        pass

    def on_straggler(self, verdict, dur_s):
        pass

    def snapshot(self, engine=None):
        return {}


NULL_TELEMETRY = _NullTelemetry()


def disabled() -> _NullTelemetry:
    """The no-op telemetry singleton (the engine default)."""
    return NULL_TELEMETRY
