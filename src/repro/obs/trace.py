"""Bounded structured event tracer for the serving engine.

Per-request lifecycle events (submit, admit, first-chunk, first-token,
per-step commit, preempt-ready, finish) and per-step events (dispatch,
ring sync, drain, defrag) land in a fixed-capacity ring buffer — the
oldest events drop, recording never blocks or grows — and export as

  * JSONL (one event object per line) for ad-hoc analysis, and
  * the Chrome trace-event format (``chrome://tracing`` / Perfetto's
    legacy JSON loader): step dispatch/sync as duration ("X") events on
    the engine track, request lifecycle as instants ("i") on one track
    per request uid.

Timestamps are ``time.perf_counter`` relative to the tracer's epoch
(microseconds in the export), so traces from one process line up across
tracks without wall-clock skew."""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional

# Chrome trace pid lanes: one synthetic "process" for the engine's step
# machinery, one for request lifecycles (tid == request uid).
PID_ENGINE = 0
PID_REQUESTS = 1


class Event:
    __slots__ = ("name", "cat", "ph", "ts_us", "dur_us", "pid", "tid",
                 "args")

    def __init__(self, name, cat, ph, ts_us, dur_us, pid, tid, args):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.ts_us = ts_us
        self.dur_us = dur_us
        self.pid = pid
        self.tid = tid
        self.args = args

    def to_chrome(self) -> Dict:
        d = {"name": self.name, "cat": self.cat, "ph": self.ph,
             "ts": self.ts_us, "pid": self.pid, "tid": self.tid}
        if self.ph == "X":
            d["dur"] = self.dur_us
        if self.ph == "i":
            d["s"] = "t"  # instant scope: thread
        if self.args:
            d["args"] = self.args
        return d


class EventTracer:
    """Fixed-capacity event ring.  ``dropped`` counts evictions, so an
    exported trace is honest about truncation."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self.epoch = time.perf_counter()
        self.total = 0

    # ------------------------------------------------------------- record

    def _now_us(self) -> float:
        return (time.perf_counter() - self.epoch) * 1e6

    def instant(self, name: str, cat: str, pid: int = PID_ENGINE,
                tid: int = 0, args: Optional[Dict] = None,
                ts_us: Optional[float] = None) -> None:
        self._push(Event(name, cat, "i",
                         self._now_us() if ts_us is None else ts_us,
                         0.0, pid, tid, args))

    def complete(self, name: str, cat: str, dur_s: float,
                 pid: int = PID_ENGINE, tid: int = 0,
                 args: Optional[Dict] = None) -> None:
        """A duration event that just ENDED (ts = now - dur)."""
        dur_us = dur_s * 1e6
        self._push(Event(name, cat, "X", self._now_us() - dur_us, dur_us,
                         pid, tid, args))

    def _push(self, ev: Event) -> None:
        self.total += 1
        self._events.append(ev)

    # ------------------------------------------------------------ queries

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return self.total - len(self._events)

    def events(self) -> List[Event]:
        return list(self._events)

    # ------------------------------------------------------------- export

    def chrome_trace(self) -> Dict:
        """chrome://tracing / Perfetto-loadable document."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": PID_ENGINE,
             "args": {"name": "serving-engine"}},
            {"name": "process_name", "ph": "M", "pid": PID_REQUESTS,
             "args": {"name": "requests"}},
        ]
        return {
            "traceEvents": meta + [e.to_chrome() for e in self._events],
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "total_events": self.total},
        }

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def export_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self._events:
                f.write(json.dumps(e.to_chrome()) + "\n")
