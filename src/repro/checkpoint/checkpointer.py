"""Topology-agnostic sharded checkpointing (no orbax in this image).

Format: one directory per step containing
  manifest.json      — pytree structure, shapes, dtypes, logical names
  <leaf-id>.npy      — each leaf as a host numpy array

Saves are ATOMIC (write to .tmp dir, fsync, rename) so a mid-save failure
never corrupts the latest checkpoint — the fault-tolerance contract.

Arrays are saved as *logical* (unsharded) values with their PartitionSpec
recorded; on restore they are device_put against the *current* mesh — so a
checkpoint written on 256 chips restores onto 512 (elastic rescale,
tests/test_checkpoint.py).  At real multi-host scale the gather/scatter
becomes per-host slice IO; the manifest layout already carries everything
needed (noted in DESIGN.md).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=()) -> Dict[Tuple[str, ...], Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (f"#{i}",)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[Tuple[str, ...], Any]):
    root: Dict = {}
    for path, v in flat.items():
        node = root
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = v

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.startswith("#") for k in keys):
            return tuple(
                rebuild(node[f"#{i}"]) for i in range(len(keys))
            )
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, tree, extra: Optional[Dict] = None) -> None:
    """Atomic save of an arbitrary (dict/tuple/array) pytree."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"leaves": [], "extra": extra or {}}
    for i, (p, leaf) in enumerate(flat.items()):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": list(p), "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_checkpoint(path: str, shardings=None):
    """Restore a pytree; ``shardings`` (matching pytree or callable
    path->sharding) re-places leaves on the current mesh."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shard_flat = None
    if shardings is not None and not callable(shardings):
        shard_flat = _flatten(shardings)
    flat = {}
    for leaf in manifest["leaves"]:
        p = tuple(leaf["path"])
        arr = np.load(os.path.join(path, leaf["file"]))
        # bf16 round-trips as npy void/uint16? numpy>=2 supports ml_dtypes names
        if leaf["dtype"] == "bfloat16" and arr.dtype != "bfloat16":
            import ml_dtypes  # shipped with jax

            arr = arr.view(ml_dtypes.bfloat16)
        if callable(shardings):
            flat[p] = jax.device_put(arr, shardings(p))
        elif shard_flat is not None and p in shard_flat:
            flat[p] = jax.device_put(arr, shard_flat[p])
        else:
            flat[p] = jax.numpy.asarray(arr)
    return _unflatten(flat), manifest.get("extra", {})


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (device_get happens on
    the caller thread to snapshot consistent values; file IO overlaps the
    next training steps)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, path: str, tree, extra=None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(path, host_tree, extra), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
