"""Checkpoint manager: rotation, resume, elastic reshard."""

from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

from .checkpointer import AsyncCheckpointer, load_checkpoint, save_checkpoint

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        async_save: bool = True,
    ):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async = AsyncCheckpointer() if async_save else None

    # ---------------------------------------------------------------- paths

    def _step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ----------------------------------------------------------------- save

    def save(self, step: int, tree, extra: Optional[Dict] = None, block: bool = False):
        path = self._step_path(step)
        if self._async is not None and not block:
            self._async.save(path, tree, extra)
        else:
            if self._async is not None:
                self._async.wait()
            save_checkpoint(path, tree, extra)
        self._rotate()

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_path(s), ignore_errors=True)

    def wait(self):
        if self._async is not None:
            self._async.wait()

    # -------------------------------------------------------------- restore

    def restore(
        self, step: Optional[int] = None, shardings=None
    ) -> Tuple[Any, Dict, int]:
        """Returns (tree, extra, step).  ``shardings`` may target a different
        mesh than the one that saved — elastic rescale."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree, extra = load_checkpoint(self._step_path(step), shardings)
        return tree, extra, step
