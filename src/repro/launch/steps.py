"""Step builders: train_step / prefill_step / decode_step with shardings.

These are the jit roots the launcher, serving engine and dry-run all share.
Every step is a pure function over (params, [opt/cache], batch) pytrees; the
sharding trees returned by ``step_shardings`` plug straight into
``jax.jit(in_shardings=..., out_shardings=...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.models.losses import chunked_xent_from_hidden, next_token_xent
from repro.obs.profiler import wrap_root
from repro.optim import (
    AdamWConfig,
    AdamWState,
    apply_updates,
    init_state,
    state_pspecs,
)
from repro.optim.grad import roundtrip
from repro.parallel.sharding import Parallelism, param_pspecs
from repro.runtime.fault import GuardConfig, guarded_update


@dataclasses.dataclass(frozen=True)
class StepConfig:
    aux_weight: float = 0.01  # MoE load-balance loss weight
    chunked_loss: int = 0  # >0: seq-chunked xent (memory optimization)
    grad_compress: bool = False  # int8+error-feedback DP gradients
    guard: Optional[GuardConfig] = GuardConfig()


# ------------------------------------------------------------------- train

def make_train_step(
    model: Model, opt_cfg: AdamWConfig, step_cfg: StepConfig = StepConfig()
) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        kwargs = {}
        if cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        elif "patches" in batch:
            kwargs["patches"] = batch["patches"]
        if step_cfg.chunked_loss and not cfg.is_encdec:
            hidden, _, aux = model.apply(
                params, batch["tokens"], mode="train", output="hidden", **kwargs
            )
            unemb = params.get("unembed", params["embed"])
            loss = chunked_xent_from_hidden(
                hidden, unemb, batch["tokens"], chunk=step_cfg.chunked_loss,
                mask=batch.get("loss_mask"),
            )
        else:
            logits, _, aux = model.apply(
                params, batch["tokens"], mode="train", **kwargs
            )
            loss = next_token_xent(logits, batch["tokens"], batch.get("loss_mask"))
        return loss + step_cfg.aux_weight * aux, (loss, aux)

    def train_step(params, opt_state, batch, grad_error=None):
        (total, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        new_error = grad_error
        if step_cfg.grad_compress:
            grads, new_error = roundtrip(grads, grad_error)
        new_params, new_opt, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, aux=aux)
        if step_cfg.guard is not None:
            (new_params, new_opt), bad = guarded_update(
                loss, metrics["grad_norm"], (new_params, new_opt),
                (params, opt_state), step_cfg.guard,
            )
            metrics["bad_step"] = bad
        if step_cfg.grad_compress:
            return new_params, new_opt, metrics, new_error
        return new_params, new_opt, metrics

    return train_step


# ------------------------------------------------------------------- serve

def make_prefill_step(model: Model, max_len: int) -> Callable:
    cfg = model.cfg

    def prefill_step(params, batch):
        b = batch["tokens"].shape[0]
        cache = model.init_cache(b, max_len)
        kwargs = {}
        if cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        elif "patches" in batch:
            kwargs["patches"] = batch["patches"]
        logits, cache, _ = model.apply(
            params, batch["tokens"], mode="prefill", cache=cache, **kwargs
        )
        return logits[:, -1:], cache

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        logits, cache, _ = model.apply(
            params,
            batch["tokens"],
            mode="decode",
            cache=cache,
            cache_len=batch["cache_len"],
        )
        return logits, cache

    return decode_step


# ------------------------------------------------------- serving jit roots
#
# The serving engine keeps ALL per-slot state (cache/pools, lengths, last
# tokens, active flags, PRNG keys) on device; these step builders are its
# only jit roots.  PRNG keys travel as raw (B, 2) uint32 key data so they
# scatter/gather with plain .at indexing.
#
# Every step donates its cache/state buffers (the *_DONATE argnum tuples
# below plug into jax.jit(donate_argnums=...)): XLA aliases each donated
# input to the same-shaped output, so the multi-MB cache is updated in place
# instead of being copied every step.  Engine rule: host-originated arrays
# (active mirror, temps, eos ids, admission token batches) are rebuilt per
# call and never donated; device state is always reassigned from the step's
# outputs, never reused.
#
# ALL finish detection happens ON DEVICE: the decode steps compare the
# sampled token against each row's eos id, decrement the row's remaining
# token ``budget`` (set at admission to max_new_tokens - 1) and check the
# max_len bound, clearing the row's active flag in the same fused call — so
# a finished row stops sampling/writing on the very next step with no host
# round-trip, WHATEVER its finish reason.  The host learns about finishes
# for free from the token vector it already transfers, and composes its own
# (possibly stale) view through the ``host_keep`` mask input.
#
# Device-authoritative exits are what make the engine's depth-K step
# pipeline sound: step N+1 can be dispatched before step N's tokens reach
# the host because a row that finishes at step N is masked by the DEVICE
# from N+1 on — the chained device state (cache, cache_len, budget, keys,
# active) is bit-identical whether the host consumed step N's transfer
# before or after dispatching N+1.  ``host_keep`` is then a pure safety
# net (it can only re-mask rows the device already masked, or rows whose
# slot the host has since retired — whose writes are garbage by contract).

def sample_tokens(key_data: jax.Array, logits: jax.Array, temps: jax.Array):
    """Vectorized per-row sampling: greedy where temps <= 0, categorical at
    logits/temp otherwise, each row drawing from its own PRNG key.

    key_data: (B, 2) uint32, logits: (B, V), temps: (B,) float32.
    Returns (new_key_data (B, 2), tokens (B,) int32).
    """

    def one(kd, lg, t):
        new_key, sub = jax.random.split(jax.random.wrap_key_data(kd))
        greedy = jnp.argmax(lg, -1).astype(jnp.int32)
        drawn = jax.random.categorical(sub, lg / jnp.maximum(t, 1e-6))
        tok = jnp.where(t > 0.0, drawn.astype(jnp.int32), greedy)
        return jax.random.key_data(new_key), tok

    return jax.vmap(one)(key_data, logits, temps)


def set_cache_rows(cache, rows, slots: jax.Array):
    """Write R per-row cache slices into batch rows ``slots``, one scatter
    per leaf.  Out-of-range slot indices are dropped (mode="drop"), which
    admission uses to pad request groups to a fixed batch shape without
    clobbering live rows."""

    def walk(c, r, name=""):
        if isinstance(c, dict):
            return {k: walk(c[k], r[k], k) for k in c}
        ax = c.ndim - _CACHE_LEAF_RULES[name][0]
        idx = (slice(None),) * ax + (slots,)
        return c.at[idx].set(r.astype(c.dtype), mode="drop")

    return walk(cache, rows)


# Device-side poison sentinel in the packed D2H token word.  Sampled
# vocab ids are >= 0 and the disabled-eos sentinel is -1, so -2 is free:
# a row whose logits go non-finite reports POISON_TOKEN instead of a
# token and clears its own active flag, and the host quarantines it off
# the transfer it already performs — no extra D2H word, no host check
# on the healthy path.
POISON_TOKEN = -2


def _sample_advance_exit(logits, last_token, cache_len, budget, key_data,
                         active, host_keep, temps, eos, max_len):
    """Shared decode-step tail: batched sampling, inactive-row masking,
    per-row length advance, and the device-side finish update (EOS sample,
    exhausted token budget, or the max_len-1 cache bound — every reason a
    host would retire the row).  Both decode builders (dense slab and
    paged) MUST share this so their sampling/exit semantics cannot
    diverge."""
    act = jnp.logical_and(active, host_keep)
    # Always-on finite check: a poisoned row (NaN/Inf logits — numerical
    # cliff or injected fault) folds POISON_TOKEN into the existing D2H
    # word and retires itself on device.  Healthy rows are untouched:
    # the wheres below select their exact sampled values bit-for-bit.
    bad = jnp.logical_and(
        act, jnp.logical_not(jnp.isfinite(logits[:, 0]).all(axis=-1)))
    new_kd, sampled = sample_tokens(key_data, logits[:, 0], temps)
    # Inactive rows FREEZE all their per-slot state — token, length,
    # budget, and PRNG key alike.  The key freeze is what makes extra
    # pipeline dispatches true no-ops: a retired slot's key chain must not
    # depend on how many garbage steps ran before the host caught up, or
    # the slot's next occupant would sample a different stream per depth.
    sampled = jnp.where(act, sampled, last_token)
    sampled = jnp.where(bad, POISON_TOKEN, sampled)
    key_data = jnp.where(act[:, None], new_kd, key_data)
    adv = act.astype(jnp.int32)
    cache_len = cache_len + adv
    budget = budget - adv
    alive = jnp.logical_and(budget > 0, cache_len < max_len - 1)
    # The active flag FREEZES too for host-masked rows (retired rows are
    # already device-dead, so freezing matches the old always-clear there):
    # a live row the scheduler temporarily withholds — stalled on block
    # growth — must still be device-active when dispatches resume, not
    # permanently retired by the masked no-op steps in between.
    new_active = jnp.logical_and(jnp.logical_and(act, sampled != eos), alive)
    new_active = jnp.logical_and(new_active, jnp.logical_not(bad))
    active = jnp.where(host_keep, new_active, active)
    return sampled, cache_len, budget, key_data, active


# donate: cache, cache_len, budget, key_data, active.  last_token is NOT
# donated: the sampled vector a step emits IS the next step's last_token,
# and the pipeline ring holds it for a still-pending D2H — donating it to
# step N+1 would delete step N's in-flight transfer.  At (B,) int32 the
# un-aliased copy is noise next to the cache.
DECODE_DONATE = (1, 3, 4, 5, 6)


def make_decode_sample_step(model: Model, max_len: int) -> Callable:
    """Fused decode + batched sampling + device-side finish exits: one
    jitted call per engine step and zero host round-trips.  Inactive rows
    keep their last_token and cache_len (their sampled garbage is masked
    out on device).  ``eos`` is a per-row token id (-1 disables); a row
    that samples its eos id, spends its last budgeted token, or hits the
    max_len-1 cache bound drops out of ``active`` in the same call.

    The chaos-variant root (RootContext.chaos) appends a trailing (B,)
    float32 ``poison`` input added to the logits: a zero vector is an
    exact identity (x + 0.0 bit-preserves finite floats), so streams are
    token-identical until the fault harness swaps in a NaN row."""

    def decode_sample_step(params, cache, last_token, cache_len, budget,
                           key_data, active, host_keep, temps, eos,
                           poison=None):
        act = jnp.logical_and(active, host_keep)
        logits, cache, _ = model.apply(
            params, last_token[:, None], mode="decode",
            cache=cache, cache_len=cache_len,
        )
        if poison is not None:
            logits = logits + poison[:, None, None]
        sampled, cache_len, budget, key_data, active = _sample_advance_exit(
            logits, last_token, cache_len, budget, key_data, active,
            host_keep, temps, eos, max_len,
        )
        return sampled, cache, cache_len, budget, key_data, active

    return decode_sample_step


# donate: pools, cache_len, budget, key_data, active (last_token stays
# un-donated — the ring may hold it for an in-flight D2H, see DECODE_DONATE)
PAGED_DECODE_DONATE = (1, 4, 5, 6, 7)


def make_paged_decode_step(model: Model, max_len: int) -> Callable:
    """Paged twin of ``decode_sample_step``: the cache is a shared block
    pool addressed through ``block_tables`` (see serving/kvcache).  Rows
    that are not effectively active get their block-table row forced to -1
    so their cache writes DROP — a freed slot's blocks may already belong to
    another request, so masking the write (not just the sampled token) is a
    correctness requirement, not an optimization."""

    def paged_decode_step(params, pools, block_tables, last_token, cache_len,
                          budget, key_data, active, host_keep, temps, eos,
                          row_order, poison=None):
        act = jnp.logical_and(active, host_keep)
        bt_eff = jnp.where(act[:, None], block_tables, -1)
        # Zero dead rows' lengths for the attention call only (real
        # cache_len still advances below): a retired slot keeps its final
        # cache_len until reuse, and the packed kernel's page loop runs to
        # the LONGEST length in each row pack — one stale 16-page row
        # would drag its whole pack through 16 junk-page DMAs per step.
        cl_eff = jnp.where(act, cache_len, 0)
        # Attention runs in scheduler-chosen row order (longest-first per
        # DP shard, dead rows last) so each packed-kernel row pack shares
        # page-loop trip counts.  Per-row math is row-independent, so
        # un-permuting the logits makes the permutation invisible to
        # sampling — and every donated array stays in slot order, keeping
        # the donation aliases intact.
        inv = jnp.argsort(row_order)
        logits_s, pools, _ = model.apply(
            params, jnp.take(last_token, row_order)[:, None], mode="decode",
            cache=pools, cache_len=jnp.take(cl_eff, row_order),
            block_tables=jnp.take(bt_eff, row_order, axis=0),
        )
        logits = jnp.take(logits_s, inv, axis=0)
        if poison is not None:
            logits = logits + poison[:, None, None]
        sampled, cache_len, budget, key_data, active = _sample_advance_exit(
            logits, last_token, cache_len, budget, key_data, active,
            host_keep, temps, eos, max_len,
        )
        return sampled, pools, cache_len, budget, key_data, active

    return paged_decode_step


# donate: pools, cache_len, last_token, budget, key_data, active
PAGED_PREFILL_DONATE = (1, 9, 10, 11, 12, 14)


def make_paged_prefill_chunk_step(model: Model) -> Callable:
    """One chunk of streaming (chunked) prefill into the paged cache, for up
    to R requests at once.  Each row r writes ``tokens[r]`` at logical
    positions ``starts[r]..starts[r]+C-1`` of its block-table row and
    attends causally over its own prefix — so a very long prompt is admitted
    as a sequence of fixed-shape chunk calls interleaved with decode steps
    instead of one monolithic prefill that stalls the running batch.

    Only ``nvalid[r]`` leading tokens of a row's chunk are real; garbage
    writes beyond them land at positions that are either masked by causality
    / cache_len or overwritten before ever becoming visible, and writes past
    the row's block reservation drop on the -1 table entries.  ``fslots[r]``
    is the row's engine slot when this chunk FINISHES its prompt (>= nslots
    otherwise): finishing rows commit cache_len/last_token/budget/keys/
    active (``budgets[r]`` is the request's remaining token budget,
    max_new_tokens - 1, feeding the device-side exit; ``row_keys[r]`` the
    REQUEST's own PRNG key, fold_in(engine seed, uid) — per-request chains
    make sampled streams independent of slot assignment and admission
    timing, which the depth-K pipeline shifts) and sample their first
    token from the last real position's logits.
    Compiles exactly once — the (R, C) shape never changes."""

    def paged_prefill_chunk_step(params, pools, bt_rows, tokens, starts,
                                 nvalid, fslots, budgets, row_keys,
                                 cache_len, last_token, budget, key_data,
                                 temps, active):
        logits, pools, _ = model.apply(
            params, tokens, mode="decode",
            cache=pools, cache_len=starts, block_tables=bt_rows,
        )
        last = jnp.take_along_axis(
            logits, jnp.maximum(nvalid - 1, 0)[:, None, None], axis=1
        )
        row_keys, first = sample_tokens(row_keys, last[:, 0], temps)
        cache_len = cache_len.at[fslots].set(starts + nvalid, mode="drop")
        last_token = last_token.at[fslots].set(first, mode="drop")
        budget = budget.at[fslots].set(budgets, mode="drop")
        key_data = key_data.at[fslots].set(row_keys, mode="drop")
        active = active.at[fslots].set(True, mode="drop")
        return first, pools, cache_len, last_token, budget, key_data, active

    return paged_prefill_chunk_step


# donate: cache, cache_len, last_token, budget, key_data, active
PREFILL_ADMIT_DONATE = (1, 7, 8, 9, 10, 12)


def make_prefill_admit_step(model: Model, max_len: int,
                            kv_quant: bool = False) -> Callable:
    """Batched multi-request admission in one jitted call: prefill R
    prompts (right-padded to a shared bucket length P), scatter their fresh
    row caches into the engine cache (replacing any previous occupant's
    rows wholesale), set per-slot lengths / last tokens / budgets / keys,
    and sample every row's first token.

    ``slots`` entries >= max_batch mark padding rows: all their writes drop,
    so admission groups keep a fixed (max_batch, P) shape and the engine
    compiles once per prompt-length bucket, not once per prompt length.
    """

    def prefill_admit_step(params, cache, tokens, plens, slots, budgets,
                           row_keys, cache_len, last_token, budget,
                           key_data, temps, active):
        row_cache = model.init_cache(tokens.shape[0], max_len,
                                     kv_quant=kv_quant)
        logits, row_cache, _ = model.apply(
            params, tokens, mode="prefill", cache=row_cache
        )
        # Last REAL position's logits per row (prompts are right-padded).
        last = jnp.take_along_axis(logits, (plens - 1)[:, None, None], axis=1)
        row_keys, first = sample_tokens(row_keys, last[:, 0], temps)
        cache = set_cache_rows(cache, row_cache, slots)
        cache_len = cache_len.at[slots].set(plens, mode="drop")
        last_token = last_token.at[slots].set(first, mode="drop")
        budget = budget.at[slots].set(budgets, mode="drop")
        key_data = key_data.at[slots].set(row_keys, mode="drop")
        active = active.at[slots].set(True, mode="drop")
        return first, cache, cache_len, last_token, budget, key_data, active

    return prefill_admit_step


# ------------------------------------------------- speculative decoding
#
# Self-speculative roots (serving/spec/): the draft root runs K+1 sequential
# cheap decodes over the DRAFT cache in one jitted call (no per-token host
# round-trips; the proposal matrix and draft probs stay on device and flow
# straight into the verify root), and the verify root feeds the K proposals
# through the same S>1 chunk-decode path chunked prefill uses, then performs
# batched accept/resample (serving/spec/verify.py) and rolls the per-row
# cache lengths to the accepted prefix — the length rollback IS the cache
# rollback: stale entries past cache_len are invisible to attention and get
# overwritten by the next chunk.  Both roots take ``block_tables=None`` for
# the dense-slab layout (the dense decode path accepts S >= 1 chunks).


# donate: pools (draft), key_data (draft)
SPEC_DRAFT_DONATE = (1, 5)


def make_spec_draft_step(model: Model, k: int) -> Callable:
    """Fused draft-K root: K+1 sequential single-token decodes of the DRAFT
    model (feed t0, sample d_1; ... feed d_{K-1}, sample d_K; feed d_K to
    cache it), emitting the (B, K) proposal matrix and the (B, K, V) draft
    probs the verifier's accept/resample needs.  Feeding all K+1 tokens —
    one more than it samples — keeps the draft cache a superset of every
    committable prefix, so draft and target lengths stay equal and no
    catch-up chunk ever exists.  Inactive rows' paged writes drop via the
    -1-forced block table; dense writes land past their own row's frozen
    cache_len, where admission's wholesale row rewrite erases them."""

    def spec_draft_step(params, pools, block_tables, last_token, cache_len,
                        key_data, active, host_keep, temps):
        act = jnp.logical_and(active, host_keep)
        bt_eff = None
        if block_tables is not None:
            bt_eff = jnp.where(act[:, None], block_tables, -1)
        # Dead rows attend at length 0 (see paged_decode_step) and their
        # key chain freezes across the scan (see _sample_advance_exit).
        cl_eff = jnp.where(act, cache_len, 0)
        kd_in = key_data

        def body(carry, i):
            tok, pools, kd = carry
            logits, pools, _ = model.apply(
                params, tok[:, None], mode="decode", cache=pools,
                cache_len=cl_eff + i, block_tables=bt_eff,
            )
            lg = logits[:, 0]
            q = jax.nn.softmax(
                lg.astype(jnp.float32)
                / jnp.maximum(temps, 1e-6)[:, None], axis=-1
            )
            kd, nxt = sample_tokens(kd, lg, temps)
            return (nxt, pools, kd), (nxt, q)

        (_, pools, key_data), (toks, qs) = jax.lax.scan(
            body, (last_token, pools, key_data),
            jnp.arange(k + 1, dtype=jnp.int32),
        )
        key_data = jnp.where(act[:, None], key_data, kd_in)
        proposals = toks[:k].T  # (B, K); the (K+1)-th sample is discarded
        q_probs = jnp.moveaxis(qs[:k], 0, 1)  # (B, K, V)
        return proposals, q_probs, pools, key_data

    return spec_draft_step


# donate: pools (target), last_token, cache_len, budget, key_data, active
SPEC_VERIFY_DONATE = (1, 3, 6, 7, 8, 9)


def make_spec_verify_step(model: Model, k: int, max_len: int) -> Callable:
    """Chunk-verification root: run the target on [t0, d_1..d_K] (one S=K+1
    chunk decode against the cache — the paged S>1 path, or the dense slab's
    chunked twin), accept/resample on device (greedy = exact prefix match;
    temperature = Leviathan accept u < p/q + residual resample, preserving
    the target distribution exactly), advance each row's cache_len by the
    m+1 committed entries [t0, d_1..d_m] — the cache-rollback contract —
    and fuse the device-side finish scan over the committed tokens (EOS,
    exhausted token ``budget``, or the max_len-1 cache bound, mirroring the
    plain decode root so pipelined spec steps stay depth-invariant).

    Returns a single packed int32 matrix for the step's ONE D2H transfer:
    ``[out_tokens (K+1) | n_commit | m]`` per row, where out_tokens is
    [d_1..d_m, t_new, fill], n_commit truncates at the first committed EOS,
    and m is the raw acceptance count for the engine's accounting."""

    from repro.serving.spec.verify import verify_tail

    def spec_verify_step(params, pools, block_tables, last_token, proposals,
                         q_probs, cache_len, budget, key_data, active,
                         host_keep, temps, eos, k_row, poison=None):
        act = jnp.logical_and(active, host_keep)
        bt_eff = None
        if block_tables is not None:
            bt_eff = jnp.where(act[:, None], block_tables, -1)
        chunk = jnp.concatenate([last_token[:, None], proposals], axis=1)
        logits, pools, _ = model.apply(
            params, chunk, mode="decode", cache=pools, cache_len=cache_len,
            block_tables=bt_eff,
        )
        if poison is not None:
            logits = logits + poison[:, None, None]
        # Always-on finite check, the spec twin of _sample_advance_exit's:
        # a poisoned row signals the host through the n_commit word it
        # already packs (-1 is unreachable: healthy n_commit >= 0) and
        # retires itself on device.  Healthy rows' wheres are identities.
        bad = jnp.logical_and(
            act, jnp.logical_not(jnp.isfinite(logits).all(axis=(1, 2))))
        new_kd, m, t_new, out_tokens = verify_tail(
            key_data, logits, q_probs, proposals, temps, k_row
        )
        # Dead rows freeze their keys (see _sample_advance_exit) so extra
        # pipelined dispatches cannot perturb a reused slot's sample chain.
        key_data = jnp.where(act[:, None], new_kd, key_data)
        t_new = jnp.where(act, t_new, last_token)
        n_raw = jnp.where(act, m + 1, 0)
        cache_len = cache_len + n_raw
        idx = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
        committed = idx < n_raw[:, None]
        is_eos = jnp.logical_and(out_tokens == eos[:, None], committed)
        any_eos = is_eos.any(axis=1)
        n_commit = jnp.where(any_eos, jnp.argmax(is_eos, axis=1) + 1, n_raw)
        # The host emits n_commit tokens (minus any it truncates at its own
        # budget/max_len bound — but those bounds clear `active` right here,
        # so the row is device-dead before the next dispatch either way).
        # Poisoned rows commit nothing: their budget freezes and the pack
        # carries the -1 quarantine sentinel instead of a commit count.
        budget = budget - jnp.where(bad, 0, n_commit)
        n_commit = jnp.where(bad, -1, n_commit)
        alive = jnp.logical_and(budget > 0, cache_len < max_len - 1)
        # Freeze (not clear) the active flag for host-masked rows — see
        # _sample_advance_exit: a scheduler-stalled row must stay
        # device-active across the masked steps it sits out.
        new_active = jnp.logical_and(
            jnp.logical_and(act, jnp.logical_not(any_eos)), alive
        )
        new_active = jnp.logical_and(new_active, jnp.logical_not(bad))
        active = jnp.where(host_keep, new_active, active)
        pack = jnp.concatenate(
            [out_tokens.astype(jnp.int32), n_commit[:, None].astype(jnp.int32),
             jnp.where(jnp.logical_and(act, jnp.logical_not(bad)), m, 0,
                       )[:, None].astype(jnp.int32)], axis=1,
        )
        return pack, pools, cache_len, t_new, budget, key_data, active

    return spec_verify_step


# donate: pools/cache (draft), key_data (draft)
PAGED_DRAFT_PREFILL_DONATE = (1, 6)
DENSE_DRAFT_PREFILL_DONATE = (1, 4)


def make_paged_draft_prefill_step(model: Model) -> Callable:
    """Draft twin of the paged prefill chunk root: stream the SAME token
    chunk into the draft pools — no sampling; the only engine-state write
    is resetting finishing rows' draft PRNG keys to the REQUEST's own draft
    chain (fold_in(draft seed, uid) — the scheduling-independence argument
    of the target roots applies to draft proposals too).  Garbage tokens
    past a row's nvalid follow the target root's argument: masked by
    causality/cache_len or overwritten before visible; writes past the
    row's draft reservation drop on -1 table entries."""

    def paged_draft_prefill_step(params, pools, bt_rows, tokens, starts,
                                 fslots, key_data, row_keys):
        _, pools, _ = model.apply(
            params, tokens, mode="decode", cache=pools, cache_len=starts,
            block_tables=bt_rows, output="hidden",
        )
        key_data = key_data.at[fslots].set(row_keys, mode="drop")
        return pools, key_data

    return paged_draft_prefill_step


def make_dense_draft_prefill_step(model: Model, max_len: int,
                                  kv_quant: bool = False) -> Callable:
    """Draft twin of the dense prefill-admit root: prefill the prompt batch
    through the DRAFT params, scatter the fresh rows into the draft slab
    (pad slots >= max_batch drop, exactly like admission), and reset the
    admitted rows' draft PRNG keys to their requests' own chains."""

    def dense_draft_prefill_step(params, cache, tokens, slots, key_data,
                                 row_keys):
        row_cache = model.init_cache(tokens.shape[0], max_len,
                                     kv_quant=kv_quant)
        _, row_cache, _ = model.apply(
            params, tokens, mode="prefill", cache=row_cache, output="hidden"
        )
        key_data = key_data.at[slots].set(row_keys, mode="drop")
        return set_cache_rows(cache, row_cache, slots), key_data

    return dense_draft_prefill_step


# ----------------------------------------------------- serving root registry
#
# Machine-readable registry of every serving jit root: the engine builds its
# jitted steps from these specs (builder + donate_argnums + sharding hook),
# and the static auditor (repro.analysis) enumerates them mechanically —
# lowering each root from abstract inputs and checking the transfer/donation/
# sharding/dtype contracts without running a decode step.  Adding a serving
# root means adding a RootSpec here; the auditor picks it up for free.

@dataclasses.dataclass(frozen=True)
class RootContext:
    """Everything needed to (re)build a serving root's jit callable and its
    abstract input pytrees: the model facade plus the engine geometry knobs.
    ``num_blocks=None`` resolves exactly like PagedKVCache's default
    (serving/kvcache.resolve_num_blocks), so audits trace the same pool the
    engine would allocate."""

    model: Model
    max_batch: int = 8
    max_len: int = 512
    kv_quant: bool = False
    prefill_chunk: int = 64
    block_size: int = 16
    num_blocks: Optional[int] = None
    spec_k: int = 4
    bucket: int = 16          # representative admission prompt bucket
    bucketed: bool = True     # models.api.prefill_pad_safe(model)
    dp_shards: int = 1
    # Chaos-variant roots: the steady sampling roots (decode /
    # paged_decode / spec_verify) take a trailing (B,) float32 poison
    # input added to the logits, so a FaultPlan can NaN one row's step
    # without recompiling.  Off (the default), roots keep their exact
    # pre-chaos signatures — the fault harness costs nothing when absent.
    chaos: bool = False

    @property
    def resolved_num_blocks(self) -> int:
        from repro.serving.kvcache import resolve_num_blocks

        return resolve_num_blocks(self.max_batch, self.max_len,
                                  self.block_size, self.num_blocks,
                                  self.dp_shards)

    @property
    def max_blocks_per_row(self) -> int:
        return -(-self.max_len // self.block_size)

    # Aval pytrees (no allocation): the cache trees every root threads.

    def cache_avals(self):
        return jax.eval_shape(
            lambda: self.model.init_cache(self.max_batch, self.max_len,
                                          kv_quant=self.kv_quant)
        )

    def pool_avals(self):
        return jax.eval_shape(
            lambda: self.model.init_paged_cache(self.resolved_num_blocks,
                                                self.block_size,
                                                kv_quant=self.kv_quant)
        )


@dataclasses.dataclass(frozen=True)
class RootSpec:
    """One serving jit root.

    ``kind`` pins the root's D2H contract class: "steady" roots run in the
    pipelined decode loop and must emit EXACTLY one device->host transfer
    (the ``d2h`` output indices), "admission" roots may sync one first-token
    vector when rows finish their prompt, "draft" roots emit nothing.

    ``build(ctx)`` returns the pure step function; ``abstract_inputs(ctx,
    params)`` its positional-argument aval pytrees (mirroring the engine's
    dispatch call exactly); ``shardings(sh, ctx, draft_params=None)`` the
    (in, out) NamedSharding pair from a ServingShardings bundle.  Spec-root
    arg 0 is the DRAFT params tree (``needs_draft``) — the auditor traces
    those with the target's avals (same architecture, any well-formed params
    pytree lowers identically)."""

    name: str
    layout: str  # "dense" | "paged"
    kind: str    # "steady" | "admission" | "draft"
    donate: Tuple[int, ...]
    d2h: Tuple[int, ...]
    build: Callable[[RootContext], Callable]
    abstract_inputs: Callable[[RootContext, Any], Tuple[Any, ...]]
    shardings: Callable
    needs_draft: bool = False


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _row_avals(b: int):
    """(i32, bool, f32, keys) per-slot aval helpers."""
    return (_sds((b,), jnp.int32), _sds((b,), jnp.bool_),
            _sds((b,), jnp.float32), _sds((b, 2), jnp.uint32))


def _chaos_tail(ctx: RootContext):
    """Trailing poison-input aval for chaos-variant sampling roots."""
    if not ctx.chaos:
        return ()
    return (_sds((ctx.max_batch,), jnp.float32),)


def _decode_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    i32, boo, f32, keys = _row_avals(b)
    return (params, ctx.cache_avals(), i32, i32, i32, keys, boo, boo, f32,
            i32) + _chaos_tail(ctx)


def _paged_decode_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    i32, boo, f32, keys = _row_avals(b)
    bt = _sds((b, ctx.max_blocks_per_row), jnp.int32)
    return (params, ctx.pool_avals(), bt, i32, i32, i32, keys, boo, boo,
            f32, i32, i32) + _chaos_tail(ctx)


def _paged_prefill_chunk_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    i32, boo, f32, keys = _row_avals(b)
    bt = _sds((b, ctx.max_blocks_per_row), jnp.int32)
    toks = _sds((b, ctx.prefill_chunk), jnp.int32)
    return (params, ctx.pool_avals(), bt, toks, i32, i32, i32, i32, keys,
            i32, i32, i32, keys, f32, boo)


def _prefill_admit_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    i32, boo, f32, keys = _row_avals(b)
    rows = b if ctx.bucketed else 1
    r_i32, _, r_f32, r_keys = _row_avals(rows)
    toks = _sds((rows, min(ctx.bucket, ctx.max_len)), jnp.int32)
    return (params, ctx.cache_avals(), toks, r_i32, r_i32, r_i32, r_keys,
            i32, i32, i32, keys, r_f32, boo)


def _spec_cache_avals(ctx: RootContext, layout: str):
    if layout == "paged":
        bt = _sds((ctx.max_batch, ctx.max_blocks_per_row), jnp.int32)
        return ctx.pool_avals(), bt
    return ctx.cache_avals(), None


def _spec_draft_inputs(layout):
    def inputs(ctx: RootContext, params):
        b = ctx.max_batch
        i32, boo, f32, keys = _row_avals(b)
        cache, bt = _spec_cache_avals(ctx, layout)
        return (params, cache, bt, i32, i32, keys, boo, boo, f32)

    return inputs


def _spec_verify_inputs(layout):
    def inputs(ctx: RootContext, params):
        b, k = ctx.max_batch, ctx.spec_k
        i32, boo, f32, keys = _row_avals(b)
        cache, bt = _spec_cache_avals(ctx, layout)
        props = _sds((b, k), jnp.int32)
        qs = _sds((b, k, ctx.model.cfg.vocab_size), jnp.float32)
        return (params, cache, bt, i32, props, qs, i32, i32, keys, boo, boo,
                f32, i32, i32) + _chaos_tail(ctx)

    return inputs


def _draft_prefill_paged_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    i32, _, _, keys = _row_avals(b)
    bt = _sds((b, ctx.max_blocks_per_row), jnp.int32)
    toks = _sds((b, ctx.prefill_chunk), jnp.int32)
    return (params, ctx.pool_avals(), bt, toks, i32, i32, keys, keys)


def _draft_prefill_dense_inputs(ctx: RootContext, params):
    b = ctx.max_batch
    _, _, _, keys = _row_avals(b)
    rows = b if ctx.bucketed else 1
    r_i32, _, _, r_keys = _row_avals(rows)
    toks = _sds((rows, min(ctx.bucket, ctx.max_len)), jnp.int32)
    return (params, ctx.cache_avals(), toks, r_i32, keys, r_keys)


def serving_root_registry(layout: str,
                          spec: bool = False) -> Tuple[RootSpec, ...]:
    """Every serving jit root for one cache layout (plus the speculative
    roots when ``spec``) — the engine's and the static auditor's single
    source of truth for builder/donation/sharding/D2H wiring.

    Every build is wrapped in ``repro.obs.profiler.wrap_root``: a
    ``jax.named_scope`` naming the root in profiler timelines / HLO dumps.
    The scope is metadata-only (no ops, no transfers) and UNCONDITIONAL, so
    engine and auditor always trace the same instrumented computation —
    the contract audits run on exactly what serves."""
    if layout not in ("dense", "paged"):
        raise ValueError(f"unknown cache layout {layout!r}")
    paged = layout == "paged"
    roots = []
    if paged:
        roots.append(RootSpec(
            "paged_decode", "paged", "steady",
            PAGED_DECODE_DONATE, (0,),
            lambda ctx: wrap_root(
                make_paged_decode_step(ctx.model, ctx.max_len),
                "paged_decode"),
            _paged_decode_inputs,
            lambda sh, ctx, draft_params=None: sh.paged_decode(
                chaos=ctx.chaos),
        ))
        roots.append(RootSpec(
            "paged_prefill_chunk", "paged", "admission",
            PAGED_PREFILL_DONATE, (0,),
            lambda ctx: wrap_root(
                make_paged_prefill_chunk_step(ctx.model),
                "paged_prefill_chunk"),
            _paged_prefill_chunk_inputs,
            lambda sh, ctx, draft_params=None: sh.paged_prefill_chunk(),
        ))
    else:
        roots.append(RootSpec(
            "decode", "dense", "steady",
            DECODE_DONATE, (0,),
            lambda ctx: wrap_root(
                make_decode_sample_step(ctx.model, ctx.max_len), "decode"),
            _decode_inputs,
            lambda sh, ctx, draft_params=None: sh.decode(chaos=ctx.chaos),
        ))
        roots.append(RootSpec(
            "prefill_admit", "dense", "admission",
            PREFILL_ADMIT_DONATE, (0,),
            lambda ctx: wrap_root(
                make_prefill_admit_step(ctx.model, ctx.max_len,
                                        kv_quant=ctx.kv_quant),
                "prefill_admit"),
            _prefill_admit_inputs,
            lambda sh, ctx, draft_params=None: sh.prefill_admit(
                bucketed=ctx.bucketed),
        ))
    if spec:
        roots.append(RootSpec(
            "spec_draft", layout, "draft",
            SPEC_DRAFT_DONATE, (),
            lambda ctx: wrap_root(
                make_spec_draft_step(ctx.model, ctx.spec_k), "spec_draft"),
            _spec_draft_inputs(layout),
            lambda sh, ctx, draft_params=None: sh.spec_draft(
                draft_params if draft_params is not None else sh.params,
                paged),
            needs_draft=True,
        ))
        roots.append(RootSpec(
            "spec_verify", layout, "steady",
            SPEC_VERIFY_DONATE, (0,),
            lambda ctx: wrap_root(
                make_spec_verify_step(ctx.model, ctx.spec_k, ctx.max_len),
                "spec_verify"),
            _spec_verify_inputs(layout),
            lambda sh, ctx, draft_params=None: sh.spec_verify(
                paged, chaos=ctx.chaos),
        ))
        if paged:
            roots.append(RootSpec(
                "draft_prefill", "paged", "draft",
                PAGED_DRAFT_PREFILL_DONATE, (),
                lambda ctx: wrap_root(
                    make_paged_draft_prefill_step(ctx.model),
                    "draft_prefill"),
                _draft_prefill_paged_inputs,
                lambda sh, ctx, draft_params=None: sh.draft_prefill_paged(
                    draft_params if draft_params is not None else sh.params),
                needs_draft=True,
            ))
        else:
            roots.append(RootSpec(
                "draft_prefill", "dense", "draft",
                DENSE_DRAFT_PREFILL_DONATE, (),
                lambda ctx: wrap_root(
                    make_dense_draft_prefill_step(
                        ctx.model, ctx.max_len, kv_quant=ctx.kv_quant),
                    "draft_prefill"),
                _draft_prefill_dense_inputs,
                lambda sh, ctx, draft_params=None: sh.draft_prefill_dense(
                    draft_params if draft_params is not None else sh.params),
                needs_draft=True,
            ))
    return tuple(roots)


# -------------------------------------------------------------- shardings

# KV caches are SEQUENCE-sharded over the model axis (context parallelism):
# it sidesteps the non-divisible-head-count archs (chatglm kv=2, phi3 h=40,
# whisper h=12) and scales to 512k caches; batch==1 long-context cells fold
# the DP axes into the sequence dim instead.
_CACHE_LEAF_RULES = {
    # leaf name -> (base ndim, (batch_dim, seq_dim, chan_dim))
    "k": (4, 1, None),
    "v": (4, 1, None),
    "c_kv": (3, 1, None),
    "k_rope": (3, 1, None),
    "h": (3, None, 1),
    "conv": (3, None, 2),
    "state": (4, None, 1),
    "shift_t": (2, None, None),
    "shift_c": (2, None, None),
    "k_scale": (3, 1, None),
    "v_scale": (3, 1, None),
}


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def cache_pspecs(cache_shapes, par: Parallelism):
    """PartitionSpec tree for a cache pytree (stack dims -> None prefix)."""
    mesh = par.mesh
    dp = par.dp
    tp = par.tp_axis

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        base_ndim, seq_dim, chan_dim = _CACHE_LEAF_RULES[name]
        pad = len(tree.shape) - base_ndim
        spec = [None] * len(tree.shape)
        shape = tree.shape
        b = shape[pad]
        batch_ok = mesh is None or b % _axis_size(mesh, dp) == 0
        if batch_ok and mesh is not None:
            spec[pad] = dp
        if seq_dim is not None and mesh is not None:
            t = shape[pad + seq_dim]
            if batch_ok:
                if t % _axis_size(mesh, tp) == 0:
                    spec[pad + seq_dim] = tp
            else:
                # batch=1 long-context: fold DP axes into the sequence dim.
                all_axes = tuple(par.dp_axes) + (tp,)
                if t % _axis_size(mesh, all_axes) == 0:
                    spec[pad + seq_dim] = all_axes
                elif t % _axis_size(mesh, tp) == 0:
                    spec[pad + seq_dim] = tp
        if chan_dim is not None and mesh is not None:
            c = shape[pad + chan_dim]
            if c % _axis_size(mesh, tp) == 0:
                spec[pad + chan_dim] = tp
        return P(*spec)

    return walk(cache_shapes)


def batch_pspecs(batch_shapes, par: Parallelism):
    mesh = par.mesh

    def walk(tree):
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        ok = mesh is None or tree.shape[0] % _axis_size(mesh, par.dp) == 0
        lead = par.dp if (ok and mesh is not None) else None
        return P(*([lead] + [None] * (len(tree.shape) - 1)))

    return walk(batch_shapes)


def logits_pspec(batch: int, vocab: int, par: Parallelism) -> P:
    mesh = par.mesh
    b_ok = mesh is not None and batch % _axis_size(mesh, par.dp) == 0
    v_ok = mesh is not None and vocab % _axis_size(mesh, par.tp_axis) == 0
    return P(par.dp if b_ok else None, None, par.tp_axis if v_ok else None)


def sanitize_pspecs(pspec_tree, shape_tree, mesh):
    """Drop sharding entries that don't divide the dim (jit boundaries
    require exact divisibility, unlike internal GSPMD constraints)."""

    def fix(spec, leaf):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        for dim, e in zip(leaf.shape, entries):
            if e is not None and dim % _axis_size(mesh, e) != 0:
                e = None
            out.append(e)
        return P(*out)

    return jax.tree.map(
        fix, pspec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P)
    )


def named(tree_pspec, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspec,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------- serving root shardings
#
# ServingShardings pins EXPLICIT in/out NamedShardings for every serving
# jit root over a DP x TP serving mesh (launch/mesh.make_serving_mesh):
#
#   * weights: TP-sharded via the existing param_pspecs (factored NSVD
#     layers all-reduce rank-k partials instead of d_model — the
#     compression shrinks the TP collective),
#   * per-slot state (last_token, cache_len, key_data, active flags) and
#     every host-built (B, ...) input (temps, eos, token chunks, block
#     tables): data-parallel over slots,
#   * the cache: dense slab over its batch dim, paged pools over their
#     block dim (models.api.serving_cache_pspecs), replicated over TP.
#
# Explicitness matters twice: donated buffers alias only when the donated
# input's sharding equals its output's (both pinned here, keeping the
# engine's in-place-cache contract), and unpinned outputs would let GSPMD
# pick a different layout than the next step's input — a silent recompile
# per step.  On a (1, 1) mesh every spec below is a no-op layout, so the
# sharded engine reproduces the single-device path bit-for-bit.

def _dp_entry(par: Parallelism, max_batch: int):
    """Spec entry for slot-indexed dims; None when slots don't divide DP
    (jit boundaries need exact divisibility — the engine then also keeps
    its block pools unsharded so host bookkeeping matches the layout)."""
    n = _axis_size(par.mesh, par.dp)
    return par.dp if max_batch % n == 0 else None


class ServingShardings:
    """NamedSharding bundles for the serving engine's jit roots.

    ``cache`` is the layout-aware cache sharding tree (dense slab or paged
    pools — models.api.serving_cache_pspecs); the draft cache shares it by
    construction (same arch, same pool geometry)."""

    def __init__(self, par: Parallelism, params, cache_shardings,
                 max_batch: int):
        mesh = par.mesh
        dp = _dp_entry(par, max_batch)
        ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
        self.par = par
        self.rep = ns()              # scalars / replicated host inputs
        self.row = ns(dp)            # (B,) per-slot state
        self.mat = ns(dp, None)      # (B, X): keys, tables, token chunks
        self.mat3 = ns(dp, None, None)  # (B, K, V) draft probs
        self.params = self.tree(params)
        self.cache = cache_shardings  # NamedSharding tree (layout-aware)

    def tree(self, shapes):
        """Param shardings for a (possibly factored/compressed) params
        pytree: the existing param_pspecs rules, sanitized against the
        actual leaf shapes (jit boundaries need exact divisibility)."""
        specs = sanitize_pspecs(param_pspecs(shapes), shapes, self.par.mesh)
        return named(specs, self.par.mesh)

    # Per-root (in_shardings, out_shardings); argument orders mirror the
    # step builders above.  ``params`` defaults to the target's tree — spec
    # roots pass the draft's (factored leaves shard identically by rule,
    # but shapes differ, so sanitization must see the right tree).

    def decode(self, params=None, chaos: bool = False):
        p = params or self.params
        tail = (self.row,) if chaos else ()
        return ((p, self.cache, self.row, self.row, self.row, self.mat,
                 self.row, self.row, self.row, self.row) + tail,
                (self.row, self.cache, self.row, self.row, self.mat,
                 self.row))

    def paged_decode(self, params=None, chaos: bool = False):
        p = params or self.params
        tail = (self.row,) if chaos else ()
        return ((p, self.cache, self.mat, self.row, self.row, self.row,
                 self.mat, self.row, self.row, self.row, self.row,
                 self.row) + tail,
                (self.row, self.cache, self.row, self.row, self.mat,
                 self.row))

    def paged_prefill_chunk(self):
        return ((self.params, self.cache, self.mat, self.mat, self.row,
                 self.row, self.row, self.row, self.mat, self.row, self.row,
                 self.row, self.mat, self.row, self.row),
                (self.row, self.cache, self.row, self.row, self.row,
                 self.mat, self.row))

    def prefill_admit(self, bucketed: bool = True):
        """``bucketed=False`` (pad-sensitive archs): admission batches are
        exact-length with rows=1, which cannot split over DP — the (R, ...)
        admission inputs and the sampled-token output stay replicated while
        cache/state keep their slot sharding (the scatter crosses shards
        under GSPMD)."""
        r = self.row if bucketed else self.rep
        m = self.mat if bucketed else self.rep
        return ((self.params, self.cache, m, r, r, r, m,
                 self.row, self.row, self.row, self.mat, r, self.row),
                (r, self.cache, self.row, self.row, self.row, self.mat,
                 self.row))

    def spec_draft(self, draft_params, paged: bool):
        bt = self.mat if paged else None
        return ((draft_params, self.cache, bt, self.row, self.row, self.mat,
                 self.row, self.row, self.row),
                (self.mat, self.mat3, self.cache, self.mat))

    def spec_verify(self, paged: bool, chaos: bool = False):
        bt = self.mat if paged else None
        tail = (self.row,) if chaos else ()
        return ((self.params, self.cache, bt, self.row, self.mat, self.mat3,
                 self.row, self.row, self.mat, self.row, self.row, self.row,
                 self.row, self.row) + tail,
                (self.mat, self.cache, self.row, self.row, self.row,
                 self.mat, self.row))

    def draft_prefill_paged(self, draft_params):
        return ((draft_params, self.cache, self.mat, self.mat, self.row,
                 self.row, self.mat, self.mat),
                (self.cache, self.mat))

    def draft_prefill_dense(self, draft_params):
        return ((draft_params, self.cache, self.mat, self.row, self.mat,
                 self.mat),
                (self.cache, self.mat))


def train_shardings(params_shape, par: Parallelism, batch_shapes, fsdp: bool = False):
    """(in_shardings, out_shardings) pspec trees for the train step."""
    p_specs = param_pspecs(params_shape, fsdp_axes=par.dp_axes if fsdp else None)
    opt_specs = state_pspecs(params_shape, p_specs, par.dp_axes)
    b_specs = batch_pspecs(batch_shapes, par)
    metrics = {
        "loss": P(), "aux": P(), "grad_norm": P(), "lr": P(), "bad_step": P()
    }
    return (p_specs, opt_specs, b_specs), (p_specs, opt_specs, metrics)


def eval_shape_opt_state(params_shape) -> AdamWState:
    return jax.eval_shape(init_state, params_shape)
