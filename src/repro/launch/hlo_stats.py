"""Parse partitioned HLO text for collective statistics.

compiled.cost_analysis() has no collective accounting, so the roofline's
collective term comes from here: for every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op we take the (per-device)
output shape and the replica-group size g, and convert to ring wire bytes:

    all-reduce       2 * s * (g-1)/g
    all-gather       s * (g-1)/g          (s = gathered output)
    reduce-scatter   s * (g-1)            (s = scattered output)
    all-to-all       s * (g-1)/g
    collective-permute  s

Ops inside while-loop bodies are multiplied by the loop trip count (parsed
from the loop condition's comparison constant) — scan-over-layers models
would otherwise undercount collectives by the layer count.
"""

from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # [n_groups,group_size] iota format
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2  # conservative default


def _computation_blocks(hlo: str) -> Dict[str, List[str]]:
    """Split module text into named computations."""
    blocks: Dict[str, List[str]] = {}
    name = None
    for line in hlo.splitlines():
        m = re.match(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$", line.strip())
        if line.strip().startswith(("ENTRY", "%")) and "{" in line and "->" in line:
            m2 = re.match(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)", line.strip())
            name = m2.group(1) if m2 else None
            blocks[name] = []
            continue
        if line.strip() == "}":
            name = None
            continue
        if name is not None:
            blocks[name].append(line)
    return blocks


def _while_trip_counts(hlo: str, blocks: Dict[str, List[str]]) -> Dict[str, int]:
    """body-computation-name -> trip count (best effort)."""
    trips: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = re.search(r"while\(.*\)\s*,\s*condition=([%\w\.\-]+),\s*body=([%\w\.\-]+)", line)
        if not m:
            continue
        cond, body = m.group(1), m.group(2)
        count = None
        for cl in blocks.get(cond, []):
            cm = re.search(r"compare\(.*\).*direction=LT", cl)
            if cm:
                km = re.search(r"constant\((\d+)\)", "\n".join(blocks.get(cond, [])))
                if km:
                    count = int(km.group(1))
                break
        # jax scans emit: cond computes iter < constant; constant may be a
        # separate op in the cond block.
        if count is None:
            consts = [
                int(x) for x in re.findall(r"constant\((\d+)\)", "\n".join(blocks.get(cond, [])))
                if int(x) > 1
            ]
            count = max(consts) if consts else 1
        trips[body] = max(trips.get(body, 1), count)
    return trips


def collective_stats(hlo: str) -> Dict[str, Dict[str, float]]:
    """Returns {op: {count, bytes, wire_bytes}} per device, plus totals.

    Collectives in while bodies are scaled by trip count; nested loops
    compose multiplicatively (body-of-body).
    """
    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo, blocks)

    # Propagate trip multipliers through nested calls (one level of nesting
    # is enough for scan-in-scan; iterate to fixpoint over 3 rounds).
    mult: Dict[str, float] = {name: 1.0 for name in blocks}
    for _ in range(3):
        for body, count in trips.items():
            if body in mult:
                # multiplier of computations called from this body
                for line in blocks.get(body, []):
                    for callee in re.findall(r"(?:condition|body|to_apply|calls)=([%\w\.\-]+)", line):
                        if callee in mult:
                            mult[callee] = max(mult[callee], mult.get(body, 1.0) * trips.get(callee, 1.0))
        for body, count in trips.items():
            mult[body] = max(mult.get(body, 1.0), count)
    # Entry-level bodies get their own trip count; computations called from
    # multiplied bodies inherit (handled above, best effort).

    stats: Dict[str, Dict[str, float]] = {}
    total_bytes = 0.0
    total_wire = 0.0
    for name, lines in blocks.items():
        scale = mult.get(name, 1.0)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            shape_txt = m.group(1) or m.group(2)
            op = m.group(3)
            size = _shape_bytes(shape_txt)
            g = _group_size(line)
            if op == "all-reduce":
                wire = 2.0 * size * (g - 1) / g
            elif op == "all-gather":
                wire = size * (g - 1) / g
            elif op == "reduce-scatter":
                wire = size * (g - 1)
            elif op == "all-to-all":
                wire = size * (g - 1) / g
            else:  # collective-permute
                wire = float(size)
            rec = stats.setdefault(op, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
            rec["count"] += scale
            rec["bytes"] += scale * size
            rec["wire_bytes"] += scale * wire
            total_bytes += scale * size
            total_wire += scale * wire
    stats["total"] = {"bytes": total_bytes, "wire_bytes": total_wire}
    return stats
