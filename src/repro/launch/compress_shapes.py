"""Shape-level compression: param ShapeDtypeStructs -> factored structs.

The dry-run of a *compressed* deployment must not run real SVDs on 671B
params; it only needs the factored parameter SHAPES.  This mirrors
core.compress.compress_params at the ShapeDtypeStruct level using the same
plan/rank machinery, so the compressed dry-run exercises exactly the
production sharding of {"u","v","u2","v2"} leaves.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping

import jax

from repro.core.nsvd import split_rank
from repro.core.plan import CompressionConfig, build_plan


def _get(tree, path):
    node = tree
    for p in path:
        node = node[p]
    return node


def _set(tree, path, value):
    node = tree
    for p in path[:-1]:
        node = node[p]
    node[path[-1]] = value


def compressed_param_shapes(
    model,
    params_shape,
    ratio: float,
    method: str = "nsvd1",
    k1_frac: float = 0.95,
    multiple_of: int = 128,
) -> Dict[str, Any]:
    """Replace each compressible kernel struct with factored structs."""
    cfg = CompressionConfig(
        method=method, ratio=ratio, k1_frac=k1_frac, multiple_of=multiple_of
    )
    plan = build_plan(model.compressible_targets(), cfg)

    def to_mut(t):
        if isinstance(t, Mapping):
            return {k: to_mut(v) for k, v in t.items()}
        return t

    out = to_mut(params_shape)
    nested = method.startswith(("nsvd", "nid"))
    for spec in plan.targets:
        leaf = _get(out, spec.path)
        kern = leaf["kernel"]
        dtype = kern.dtype
        k = plan.rank_of(spec)
        lead = tuple(spec.stacked)
        if nested:
            k1, k2 = split_rank(k, k1_frac)
        else:
            k1, k2 = k, 0
        factored = {
            "u": jax.ShapeDtypeStruct((*lead, spec.in_dim, k1), dtype),
            "v": jax.ShapeDtypeStruct((*lead, k1, spec.out_dim), dtype),
        }
        if k2 > 0:
            factored["u2"] = jax.ShapeDtypeStruct((*lead, spec.in_dim, k2), dtype)
            factored["v2"] = jax.ShapeDtypeStruct((*lead, k2, spec.out_dim), dtype)
        _set(out, spec.path, factored)
    return out
