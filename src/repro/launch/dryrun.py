import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  512 host-platform devices back both the single-pod
(16 x 16 = 256) and multi-pod (2 x 16 x 16 = 512) production meshes.

Per cell this driver:
  1. builds the model against the production mesh,
  2. jit-lowers the right step (train_step / prefill_step / decode_step)
     with full in/out shardings (ShapeDtypeStruct inputs — no allocation),
  3. compiles, printing memory_analysis() and cost_analysis(),
  4. parses the partitioned HLO for collective bytes (roofline input),
  5. writes a JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --subprocess  # isolate cells
"""

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax

from repro.configs import SHAPE_CASES, applicable_shapes, get_config
from repro.configs.registry import ASSIGNED
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    StepConfig,
    batch_pspecs,
    cache_pspecs,
    eval_shape_opt_state,
    logits_pspec,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    named,
    sanitize_pspecs,
    train_shardings,
)
from repro.models.api import build_model, input_specs
from repro.optim import AdamWConfig
from repro.parallel.sharding import make_parallelism, param_pspecs

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mem_dict(mem) -> Dict[str, float]:
    return {
        k: float(getattr(mem, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }


def dryrun_cell(
    arch: str,
    shape: str,
    multi_pod: bool = False,
    unroll: bool = False,
    compressed_ratio: Optional[float] = None,
    chunked_loss: int = 1024,
    fsdp: bool = True,
    seq_parallel: bool = False,
    kv_quant: bool = False,
    verbose: bool = True,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    case = SHAPE_CASES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = make_parallelism(mesh)
    n_chips = mesh.size

    model = build_model(cfg, par, remat=(case.kind == "train"), unroll=unroll,
                        seq_parallel=seq_parallel)
    params_shape = jax.eval_shape(model.init, jax.random.key(0))

    if compressed_ratio is not None:
        from repro.launch.compress_shapes import compressed_param_shapes

        params_shape = compressed_param_shapes(model, params_shape, compressed_ratio)

    batch = input_specs(cfg, case)
    t0 = time.time()
    fsdp_axes = par.dp_axes if (fsdp and case.kind == "train") else None
    p_pspecs = param_pspecs(params_shape, fsdp_axes=fsdp_axes)

    with jax.set_mesh(mesh):
        if case.kind == "train":
            step = make_train_step(
                model,
                AdamWConfig(),
                StepConfig(chunked_loss=chunked_loss if not cfg.is_encdec else 0),
            )
            opt_shape = eval_shape_opt_state(params_shape)
            (pi, oi, bi), (po, oo, mo) = train_shardings(
                params_shape, par, batch, fsdp=fsdp
            )
            pi = sanitize_pspecs(pi, params_shape, mesh)
            po = pi
            oi = jax.tree.map(
                lambda s_, l: sanitize_pspecs(s_, l, mesh) if hasattr(l, "shape") else s_,
                oi, opt_shape,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )
            oo = oi
            bi = sanitize_pspecs(bi, batch, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(named(pi, mesh), named(oi, mesh), named(bi, mesh)),
                out_shardings=(named(po, mesh), named(oo, mesh), named(mo, mesh)),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, batch)
        elif case.kind == "prefill":
            step = make_prefill_step(model, max_len=case.seq_len)
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(case.global_batch, case.seq_len)
            )
            c_specs = cache_pspecs(cache_shape, par)
            b_specs = sanitize_pspecs(batch_pspecs(batch, par), batch, mesh)
            p_in = sanitize_pspecs(p_pspecs, params_shape, mesh)
            lspec = logits_pspec(case.global_batch, cfg.vocab_size, par)
            jitted = jax.jit(
                step,
                in_shardings=(named(p_in, mesh), named(b_specs, mesh)),
                out_shardings=(
                    named(lspec, mesh),
                    named(c_specs, mesh),
                ),
            )
            lowered = jitted.lower(params_shape, batch)
        else:  # decode
            step = make_decode_step(model)
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(case.global_batch, case.seq_len,
                                         kv_quant=kv_quant)
            )
            c_specs = cache_pspecs(cache_shape, par)
            b_specs = sanitize_pspecs(batch_pspecs(batch, par), batch, mesh)
            p_in = sanitize_pspecs(p_pspecs, params_shape, mesh)
            lspec = logits_pspec(case.global_batch, cfg.vocab_size, par)
            jitted = jax.jit(
                step,
                in_shardings=(
                    named(p_in, mesh),
                    named(c_specs, mesh),
                    named(b_specs, mesh),
                ),
                out_shardings=(
                    named(lspec, mesh),
                    named(c_specs, mesh),
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, cache_shape, batch)

        lower_s = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": case.kind,
        "unroll": unroll,
        "fsdp": bool(fsdp_axes),
        "compressed_ratio": compressed_ratio,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory": _mem_dict(mem),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
    }
    if verbose:
        print(f"[{arch} | {shape} | {result['mesh']}] "
              f"lower={lower_s:.1f}s compile={compile_s:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={result['flops_per_device']:.3e} "
              f"bytes={result['bytes_per_device']:.3e}")
        print(f"  collectives: {json.dumps(coll, indent=None)}")
    return result


def save_result(result: Dict[str, Any], suffix: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}{suffix}.json"
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def iter_cells(multi_pod_too: bool = True):
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape, False
            if multi_pod_too:
                yield arch, shape, True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (isolation)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans (roofline-exact flop accounting)")
    ap.add_argument("--ratio", type=float, default=None,
                    help="NSVD compression ratio for compressed-model dry-run")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        failures = []
        for arch, shape, mp in iter_cells():
            if args.subprocess:
                import subprocess

                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if mp:
                    cmd.append("--multi-pod")
                if args.unroll:
                    cmd.append("--unroll")
                rc = subprocess.run(cmd).returncode
                if rc != 0:
                    failures.append((arch, shape, mp))
            else:
                try:
                    r = dryrun_cell(arch, shape, mp, unroll=args.unroll,
                                    compressed_ratio=args.ratio,
                                    fsdp=not args.no_fsdp)
                    save_result(r)
                except Exception as e:  # noqa: BLE001
                    print(f"FAILED {arch} {shape} mp={mp}: {e}")
                    failures.append((arch, shape, mp))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells compiled OK")
        return

    r = dryrun_cell(
        args.arch, args.shape, args.multi_pod, unroll=args.unroll,
        compressed_ratio=args.ratio, fsdp=not args.no_fsdp,
        seq_parallel=args.seq_parallel, kv_quant=args.kv_quant,
    )
    suffix = "_unroll" if args.unroll else ""
    if args.ratio is not None:
        suffix += f"_r{int(args.ratio * 100)}"
    if args.seq_parallel:
        suffix += "_sp"
    if args.kv_quant:
        suffix += "_kvq"
    path = save_result(r, suffix)
    print("saved:", path)


if __name__ == "__main__":
    main()
