"""Training launcher: config -> mesh -> fault-tolerant train loop.

Production behaviors wired here (exercised at small scale in
tests/test_train_loop.py and examples/train_lowrank.py):
  * deterministic restart-safe data pipeline (state in the checkpoint)
  * async checkpointing with rotation + atomic renames
  * step guard (NaN/divergence -> skip), rollback after repeated faults
  * straggler watchdog hooks
  * optional int8+error-feedback gradient compression
"""

from __future__ import annotations

import argparse
import logging
import time
from typing import Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import LMDataPipeline, PipelineState
from repro.launch.steps import StepConfig, make_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, init_state, linear_warmup_cosine
from repro.runtime.fault import FaultHandler, GuardConfig
from repro.runtime.straggler import StepTimeWatchdog

logger = logging.getLogger(__name__)


def train_loop(
    arch: str = "small-llama",
    steps: int = 200,
    batch: int = 8,
    seq: int = 128,
    lr: float = 1e-3,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    resume: bool = True,
    reduced: bool = True,
    grad_compress: bool = False,
    seed: int = 0,
):
    if arch in ("small-llama", "small-opt", "small-mistral"):
        import benchmarks.common as bc

        cfg = bc.get_small_config(arch)
    else:
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    opt_cfg = AdamWConfig(lr=lr, schedule=linear_warmup_cosine(20, steps))
    opt = init_state(params)
    step_cfg = StepConfig(grad_compress=grad_compress)
    step_fn = jax.jit(make_train_step(model, opt_cfg, step_cfg))

    pipe_state = PipelineState(seed=seed, step=0, domain="en_a")
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    handler = FaultHandler(GuardConfig(), mgr)
    watchdog = StepTimeWatchdog()

    if mgr is not None and resume and mgr.latest_step() is not None:
        (params, opt), extra, start_step = mgr.restore()
        from repro.optim import AdamWState

        opt = AdamWState(*opt)  # checkpointer round-trips NamedTuple as tuple
        pipe_state = PipelineState.from_dict(extra["pipeline"])
        logger.info("resumed from step %d", start_step)

    pipe = LMDataPipeline(cfg.vocab_size, batch, seq, pipe_state)
    grad_error = None
    metrics = {}
    for step in range(start_step, steps):
        watchdog.step_start()
        b = next(pipe)
        if grad_compress:
            params, opt, metrics, grad_error = step_fn(params, opt, b, grad_error)
        else:
            params, opt, metrics = step_fn(params, opt, b)
        verdict = watchdog.step_end()
        action = handler.observe(bool(metrics.get("bad_step", False)))
        if action == "reload" and mgr is not None:
            (params, opt), extra, rstep = mgr.restore()
            pipe.state = PipelineState.from_dict(extra["pipeline"])
            logger.warning("rolled back to step %d", rstep)
            continue
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt),
                     {"pipeline": pipe.state.to_dict()})
        if verdict == "trip":
            logger.warning("straggler watchdog tripped (median %.3fs)",
                           watchdog.median_step)
    if mgr is not None:
        mgr.save(steps, (params, opt), {"pipeline": pipe.state.to_dict()},
                 block=True)
    return params, opt, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-llama")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    t0 = time.time()
    _, _, metrics = train_loop(
        arch=args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, grad_compress=args.grad_compress,
    )
    print(f"done in {time.time()-t0:.1f}s; final metrics: "
          f"{ {k: float(v) for k, v in metrics.items()} }")


if __name__ == "__main__":
    main()
