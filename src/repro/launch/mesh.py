"""Mesh factories (production + serving).

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — required by the dry-run contract.
"""

from __future__ import annotations

import warnings

import jax

try:  # jax >= 0.5 spells mesh axis types explicitly
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:  # older jax: every axis is Auto already
    _AXIS_KW = lambda n: {}  # noqa: E731

# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024**3


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes):
    """General mesh for tests/examples (1x1 meshes exercise the same code)."""
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_serving_mesh(dp: int = 1, tp: int = 1):
    """Serving mesh: ("data", "model") of shape (dp, tp), validated against
    the actual device count.

    Unlike ``make_production_mesh`` (which hard-requires 256 chips), this
    factory is safe on small hosts: when dp*tp exceeds
    ``jax.device_count()`` it WARNS and falls back to a (1, 1) mesh —
    which the serving engine guarantees is bit-for-bit identical to the
    meshless single-device path — instead of letting ``jax.make_mesh``
    raise.  Run tests/CI with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise
    real (2, 2) meshes on CPU."""
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh axes must be positive, got dp={dp} tp={tp}")
    n = dp * tp
    avail = jax.device_count()
    if n > avail:
        warnings.warn(
            f"serving mesh dp x tp = {dp}x{tp} needs {n} devices but only "
            f"{avail} are available; falling back to a (1, 1) mesh "
            f"(single-device-equivalent). Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} to emulate on CPU.",
            stacklevel=2,
        )
        dp = tp = 1
    return jax.make_mesh((dp, tp), ("data", "model"), **_AXIS_KW(2))
