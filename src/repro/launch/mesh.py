"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run contract.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
CHIP_HBM_BYTES = 16 * 1024**3


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """General mesh for tests/examples (1x1 meshes exercise the same code)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
