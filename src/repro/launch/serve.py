"""Serving launcher: load (or compress) a model and run batched requests."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-llama")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--compress", type=float, default=None,
                    help="NSVD ratio (requires calibration pass)")
    args = ap.parse_args()

    if args.arch.startswith("small-"):
        from benchmarks.common import train_small_lm

        model, params, _ = train_small_lm(args.arch)
        cfg = model.cfg
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))

    if args.compress is not None:
        from benchmarks.common import get_grams
        from repro.core import CompressionConfig, build_plan, compress_params

        grams = get_grams(args.arch, model, params)
        plan = build_plan(
            model.compressible_targets(),
            CompressionConfig(method="nsvd1", ratio=args.compress,
                              dtype="float32", use_randomized=False),
        )
        params = compress_params(params, plan, grams)
        print(f"serving NSVD-compressed weights ({plan.achieved_ratio:.0%} removed)")

    eng = ServingEngine(model, params, max_batch=4, max_len=256)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(2, cfg.vocab_size // 2, size=8),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    out = eng.run()
    dt = time.time() - t0
    n = sum(len(v) for v in out.values())
    print(f"{len(out)} requests, {n} tokens, {n/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
