"""Serving launcher: load (or compress) a model and run batched requests.

Mesh-sharded serving (``--dp``/``--tp``): the engine runs its decode /
chunked-prefill / speculative roots SPMD over a (dp, tp) mesh — weights
tensor-parallel, slots + KV pools data-parallel.  A (1, 1) mesh (or no
flags) is bit-for-bit the single-device engine.  Examples:

    # 4-chip host: 2-way data x 2-way tensor parallel, paged cache
    python -m repro.launch.serve --dp 2 --tp 2 --max-batch 8

    # emulate the same on CPU
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.launch.serve --dp 2 --tp 2 --max-batch 8

``--max-batch`` should be a multiple of ``--dp`` (otherwise per-slot state
stays replicated and only the weights shard); ``--num-blocks`` rounds up
to a multiple of ``--dp`` so every shard holds an equal sub-pool."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-llama")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", type=float, default=None,
                    help="NSVD ratio (requires calibration pass)")
    ap.add_argument("--paged", choices=("auto", "on", "off"), default="auto",
                    help="KV-cache layout (auto: paged for attention models)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool size (default: dense-capacity parity)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--eos", type=int, default=None,
                    help="EOS token id (device-side early exit)")
    ap.add_argument("--spec-ratio", type=float, default=None,
                    help="enable self-speculative decoding with a draft "
                         "compressed at this (higher) NSVD ratio")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculation window: draft tokens per step")
    ap.add_argument("--spec-dynamic-k", action="store_true",
                    help="per-row adaptive speculation windows")
    ap.add_argument("--sched-policy", choices=("on_demand", "worst_case"),
                    default="on_demand",
                    help="paged admission policy: on_demand admits on "
                         "prompt-sized reservations and grows per decode "
                         "step at block boundaries; worst_case reserves "
                         "prompt+max_new up front (the pre-scheduler "
                         "contract)")
    ap.add_argument("--priority-classes", default=None, metavar="A,B,...",
                    help="comma-separated latency classes, highest "
                         "priority first (default: single 'default' "
                         "class, plain FIFO); requests here all land in "
                         "the lowest class")
    ap.add_argument("--no-preempt", action="store_true",
                    help="never evict a live row when the block pool runs "
                         "dry: starved rows stall (frozen on device) "
                         "until blocks free up, and a genuine full-pool "
                         "deadlock raises instead of thrashing")
    ap.add_argument("--pipeline-depth", type=int, default=None,
                    help="in-flight decode steps (default 2, or the "
                         "REPRO_SERVING_PIPELINE_DEPTH env var): the engine "
                         "dispatches step N+1 before consuming step N's "
                         "token transfer, overlapping host token/slot "
                         "bookkeeping with device compute. 1 disables the "
                         "overlap (bit-for-bit the serial engine); any "
                         "depth produces identical token streams")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh axis: slots, per-slot state "
                         "and KV pools shard over dp devices (max-batch "
                         "should divide it)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel mesh axis: weights shard over tp "
                         "devices (factored NSVD layers all-reduce rank-k "
                         "partials, so TP collectives shrink with "
                         "compression). dp*tp must fit jax.device_count() "
                         "or the mesh falls back to (1,1) with a warning; "
                         "use XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to emulate on CPU")
    ap.add_argument("--audit", action="store_true",
                    help="preflight the static contract auditor "
                         "(repro.analysis) over this run's serving roots "
                         "before serving; refuses to start on a violation")
    ap.add_argument("--transfer-guard", action="store_true",
                    help="run the steady-state decode loop under "
                         "jax.transfer_guard('disallow'): any implicit "
                         "host<->device transfer raises instead of "
                         "silently stalling the step pipeline (also via "
                         "REPRO_SERVING_TRANSFER_GUARD=1)")
    fault_g = ap.add_argument_group(
        "fault tolerance", "deterministic chaos + degradation policy "
        "(repro.serving.faults); off by default — an engine without a "
        "plan takes no extra hot-path branches")
    fault_g.add_argument("--chaos", default=None, metavar="PLAN.json",
                         help="inject the FaultPlan in PLAN.json "
                              '({"faults": [{"kind": "straggler", '
                              '"step": 4}, ...]}): the engine must absorb '
                              "every fault without perturbing healthy "
                              "token streams; a fault report prints at "
                              "exit")
    fault_g.add_argument("--max-retries", type=int, default=0,
                         help="poisoned-request retry budget (reprefill "
                              "from committed context with capped "
                              "exponential backoff) before the request "
                              "retires with finish_reason='error'")
    fault_g.add_argument("--step-timeout", type=float, default=None,
                         metavar="SECONDS",
                         help="hard per-step wall-clock limit: exceeding "
                              "it raises a structured ServingFault with "
                              "an engine snapshot for post-mortem")
    obs_g = ap.add_argument_group(
        "observability", "host-side telemetry (repro.obs): any flag here "
        "enables the tracer + metrics registry; all are off by default "
        "and the disabled path is a pinned no-op")
    obs_g.add_argument("--metrics-port", type=int, default=None,
                       help="serve Prometheus text at :PORT/metrics, a "
                            "JSON snapshot at :PORT/metrics.json and a "
                            "liveness probe at :PORT/healthz while "
                            "running (0 picks a free port)")
    obs_g.add_argument("--metrics-json", default=None, metavar="PATH",
                       help="write a final JSON metrics snapshot here")
    obs_g.add_argument("--trace-jsonl", default=None, metavar="PATH",
                       help="export the event ring buffer as JSONL")
    obs_g.add_argument("--trace-chrome", default=None, metavar="PATH",
                       help="export the event ring buffer as a Chrome "
                            "trace (load in chrome://tracing or Perfetto)")
    obs_g.add_argument("--profile-dir", default=None, metavar="DIR",
                       help="capture a jax.profiler trace of the first "
                            "--profile-steps decode steps into DIR")
    obs_g.add_argument("--profile-steps", type=int, default=8,
                       help="steps to profile with --profile-dir")
    args = ap.parse_args()

    if args.arch.startswith("small-"):
        from benchmarks.common import train_small_lm

        model, params, _ = train_small_lm(args.arch)
        cfg = model.cfg
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))

    # Observability comes up BEFORE compression so the compression pass
    # (when --compress rides along) reports into the same registry the
    # serving loop exports on --metrics-port.
    telemetry = None
    metrics_server = None
    compress_telemetry = None
    # The metrics server is built before the engine exists; /healthz reads
    # the engine's degradation surface through this late-bound reference.
    health_ref = {}
    obs_wanted = any(v is not None for v in (
        args.metrics_port, args.metrics_json, args.trace_jsonl,
        args.trace_chrome, args.profile_dir))
    if obs_wanted:
        from repro.obs import CompressionTelemetry, MetricsServer, Telemetry

        telemetry = Telemetry(profile_dir=args.profile_dir,
                              profile_steps=args.profile_steps)
        compress_telemetry = CompressionTelemetry(registry=telemetry.metrics)
        if args.metrics_port is not None:
            metrics_server = MetricsServer(
                telemetry.metrics, port=args.metrics_port,
                health=lambda: (health_ref["eng"].degraded_components()
                                if "eng" in health_ref else {}))
            print(f"metrics: {metrics_server.url} "
                  "(+ /metrics.json, /healthz)")

    base_params = params
    if args.compress is not None:
        from benchmarks.common import get_grams
        from repro.core import CompressionConfig, build_plan, compress_params

        grams = get_grams(args.arch, model, params)
        plan = build_plan(
            model.compressible_targets(),
            CompressionConfig(method="nsvd1", ratio=args.compress,
                              dtype="float32", use_randomized=False),
        )
        params = compress_params(base_params, plan, grams,
                                 telemetry=compress_telemetry)
        print(f"serving NSVD-compressed weights ({plan.achieved_ratio:.0%} removed)")

    spec_config = None
    if args.spec_ratio is not None:
        from benchmarks.common import get_grams
        from repro.models.api import build_draft_params
        from repro.serving.spec import SpecConfig

        grams = get_grams(args.arch, model, base_params)
        draft_params = build_draft_params(model, base_params, grams,
                                          args.spec_ratio)
        spec_config = SpecConfig(draft_params=draft_params, k=args.spec_k,
                                 dynamic_k=args.spec_dynamic_k,
                                 draft_ratio=args.spec_ratio)
        print(f"speculative decoding: nsvd-{args.spec_ratio:.0%} draft, "
              f"k={args.spec_k}"
              + (" (dynamic per-row)" if args.spec_dynamic_k else ""))

    parallelism = None
    if args.dp * args.tp > 1:
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel.sharding import make_parallelism

        mesh = make_serving_mesh(args.dp, args.tp)
        parallelism = make_parallelism(mesh)
        print(f"serving mesh: dp={mesh.shape['data']} "
              f"tp={mesh.shape['model']} ({mesh.size} device(s))")

    if args.audit:
        from repro.analysis.run import audit_layout
        from repro.models.api import cache_layout, param_specs

        native = cache_layout(model)
        layout = {"auto": native, "on": "paged", "off": "dense"}[args.paged]
        rows = audit_layout(model, param_specs(cfg), layout, parallelism,
                            spec=spec_config is not None,
                            max_batch=args.max_batch, max_len=args.max_len,
                            spec_k=args.spec_k)
        bad = [r["root"] for r in rows if not r["ok"]]
        if bad:
            raise SystemExit(
                f"serving-root contract audit FAILED for {bad}; run "
                "python -m repro.analysis.run for the full report")
        print(f"audit: {len(rows)} {layout} roots clean "
              "(transfers/donation/sharding/dtypes)")

    faults = None
    fault_policy = None
    if (args.chaos is not None or args.max_retries
            or args.step_timeout is not None):
        from repro.serving.faults import FaultPlan, FaultPolicy

        if args.chaos is not None:
            faults = FaultPlan.from_json(args.chaos)
            print(f"chaos: {len(faults)} seeded fault(s) from {args.chaos}")
        fault_policy = FaultPolicy(max_retries=args.max_retries,
                                   step_timeout_s=args.step_timeout)

    from repro.serving.scheduler import SchedulerConfig

    sched_config = SchedulerConfig(
        admission=args.sched_policy,
        preempt=not args.no_preempt,
        priority_classes=tuple(
            c.strip() for c in args.priority_classes.split(",") if c.strip())
        if args.priority_classes else ("default",),
    )
    eng = ServingEngine(model, params, max_batch=args.max_batch,
                        max_len=args.max_len, seed=args.seed,
                        paged={"auto": None, "on": True, "off": False}[args.paged],
                        block_size=args.block_size,
                        num_blocks=args.num_blocks,
                        prefill_chunk=args.prefill_chunk,
                        eos_id=args.eos,
                        spec_config=spec_config,
                        parallelism=parallelism,
                        pipeline_depth=args.pipeline_depth,
                        transfer_guard=args.transfer_guard or None,
                        telemetry=telemetry,
                        sched_config=sched_config,
                        faults=faults,
                        fault_policy=fault_policy)
    health_ref["eng"] = eng
    # SIGTERM = graceful drain: stop admitting, shed the queue, let live
    # rows finish their in-flight steps, then run() returns normally.
    import signal

    signal.signal(signal.SIGTERM, lambda *_: eng.request_drain())
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        eng.submit(rng.integers(2, cfg.vocab_size // 2, size=8),
                   max_new_tokens=args.max_new,
                   temperature=args.temperature)
    t0 = time.time()
    # The metrics server thread must come down with the engine, crash or
    # clean exit alike — a daemon thread holding the port would outlive a
    # failed run in long-lived launchers.
    try:
        out = eng.run()
    except BaseException:
        if metrics_server is not None:
            metrics_server.close()
        raise
    finally:
        # Idempotent engine teardown: sheds anything still queued/parked
        # and retires live rows with finish_reason='shutdown' (a no-op
        # after a clean run; best-effort when unwinding a ServingFault).
        try:
            eng.close()
        except Exception:
            pass
    dt = time.time() - t0
    n = sum(len(v) for v in out.values())
    print(f"{len(out)} requests, {n} tokens, {n/dt:.1f} tok/s")
    s = eng.stats()
    if s.get("steps"):
        print(f"decode steps: {s['steps']} (pipeline depth "
              f"{s['pipeline_depth']})  "
              f"p50={s['step_p50_s']*1e3:.2f}ms  "
              f"p90={s['step_p90_s']*1e3:.2f}ms  "
              f"p99={s['step_p99_s']*1e3:.2f}ms  "
              f"[device wait {s['device_wait_mean_s']*1e3:.2f}ms + host "
              f"{s['host_mean_s']*1e3:.2f}ms per step]")
    cs = eng.cache_stats()
    extra = (f"  peak blocks={cs['blocks_peak']}/{cs['num_blocks']}"
             if cs["layout"] == "paged" else "")
    if cs.get("blocks_peak_by_shard"):
        extra += f"  per-shard peaks={cs['blocks_peak_by_shard']}"
    mesh_s = cs["mesh"]
    per_dev = (f" ({cs['per_device_cache_hbm_bytes']/1e6:.2f}MB/device, "
               f"dp={mesh_s['dp']} tp={mesh_s['tp']})"
               if mesh_s["devices"] > 1 else "")
    print(f"cache[{cs['layout']}]: {cs['cache_hbm_bytes']/1e6:.2f}MB{per_dev}, "
          f"capacity {cs['tokens_capacity']} tok{extra}")
    sch = eng.scheduler_stats()
    if cs["layout"] == "paged":
        occ = sch["occupancy_live_frac"]
        occ_s = f"{occ:.0%}" if occ is not None else "n/a"
        print(f"sched[{sch['admission_policy']}]: live/reserved {occ_s}, "
              f"{sch['preempt_count']} preempts, {sch['resumes']} resumes, "
              f"{sch['grown_blocks']} grown blocks, {sch['stalls']} stalls, "
              f"swap {sch['swap_bytes']/1e6:.2f}MB")
    ss = eng.spec_stats()
    if ss:
        print(f"spec[k={ss['k']}]: acceptance {ss['acceptance_rate']:.0%}, "
              f"{ss['committed_per_row_step']:.2f} committed tok/row-step, "
              f"draft cache {ss['draft_hbm_bytes']/1e6:.2f}MB")
    if faults is not None or fault_policy is not None:
        fs = eng.fault_stats()
        inj = ", ".join(f"{k}={v}" for k, v in sorted(fs["injected"].items()))
        print(f"faults: injected [{inj or 'none'}], "
              f"quarantined={fs['quarantined']} retried={fs['retried']} "
              f"shed={fs['shed']} swap_fallbacks={fs['swap_fallbacks']} "
              f"draft_kills={fs['draft_kills']}/"
              f"reenables={fs['draft_reenables']} "
              f"straggler slow/trips={fs['straggler_slow']}/"
              f"{fs['straggler_trips']}")
        if faults is not None and faults.outstanding():
            kinds = [sp.kind for sp in faults.outstanding()]
            print(f"faults: {len(kinds)} spec(s) never found an injection "
                  f"site: {kinds}")

    if telemetry is not None:
        if telemetry.profile is not None:
            telemetry.profile.stop()
        bb = telemetry.bench_block()
        print(f"telemetry: ttft p50={bb['ttft_s']['p50']*1e3:.1f}ms "
              f"p99={bb['ttft_s']['p99']*1e3:.1f}ms  "
              f"tpot p50={bb['tpot_s']['p50']*1e3:.2f}ms  "
              f"{len(telemetry.tracer)} events "
              f"({telemetry.tracer.dropped} dropped)")
        if args.metrics_json:
            from repro.obs import write_metrics_json

            write_metrics_json(telemetry.metrics, args.metrics_json,
                               extra={"engine": {"stats": s, "cache": cs,
                                                 "spec": ss}})
            print(f"metrics snapshot -> {args.metrics_json}")
        if args.trace_jsonl:
            telemetry.tracer.export_jsonl(args.trace_jsonl)
            print(f"event trace (jsonl) -> {args.trace_jsonl}")
        if args.trace_chrome:
            telemetry.tracer.export_chrome(args.trace_chrome)
            print(f"chrome trace -> {args.trace_chrome}")
        if args.profile_dir:
            print(f"jax.profiler trace -> {args.profile_dir}")
        if metrics_server is not None:
            metrics_server.close()


if __name__ == "__main__":
    main()
