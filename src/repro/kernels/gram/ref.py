"""Pure-jnp oracle for the Gram kernel."""

import jax.numpy as jnp
from jax import lax


def gram_accumulate_ref(x):
    n = x.shape[-1]
    flat = x.reshape(-1, n).astype(jnp.float32)
    return lax.dot_general(
        flat, flat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
