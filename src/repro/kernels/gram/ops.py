"""jit'd wrapper: padding + backend dispatch for the Gram kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .gram import gram_accumulate as _kernel_call
from .ref import gram_accumulate_ref


def gram_accumulate(x, block_n: int = 256, block_t: int = 512,
                    interpret: bool = False, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if not use_kernel:
        return gram_accumulate_ref(x)
    n = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2d = x.reshape(rows, n)
    bn = min(block_n, n)
    bt = min(block_t, rows)
    pad_n = (-n) % bn
    pad_t = (-rows) % bt
    if pad_n or pad_t:
        x2d = jnp.pad(x2d, [(0, pad_t), (0, pad_n)])
    g = _kernel_call(x2d, block_n=bn, block_t=bt, interpret=interpret)
    if pad_n:
        g = g[:n, :n]
    return g
