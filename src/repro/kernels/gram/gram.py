"""Pallas TPU kernel: streaming Gram update  G += X^T X  (calibration).

The calibration pass is bandwidth-bound: every activation tensor is read
once and reduced into an (n, n) fp32 Gram.  The kernel tiles the (n, n)
output on a 2-D grid and streams X in row-chunks, accumulating on the MXU
in fp32 — one HBM pass over X per Gram instead of the two (matmul +
accumulate) of the unfused path, and the accumulation happens in VMEM.

Grid: (n/bi, n/bj, T/bt); the T axis is the reduction — Pallas revisits the
same output tile for each t step (output index map ignores t), so the
accumulator lives in the output ref across t steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_i_ref, x_j_ref, g_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _():
        g_ref[...] = jnp.zeros_like(g_ref)

    xi = x_i_ref[...]  # (bt, bi)
    xj = x_j_ref[...]  # (bt, bj)
    g_ref[...] += jnp.dot(
        xi.T, xj, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "interpret"))
def gram_accumulate(
    x: jax.Array,
    block_n: int = 256,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x: (..., n) -> (n, n) fp32 Gram of the flattened rows."""
    n = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2d = x.reshape(rows, n)
    bn = min(block_n, n)
    bt = min(block_t, rows)
    grid = (n // bn, n // bn, rows // bt)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bn), lambda i, j, t: (t, i)),
            pl.BlockSpec((bt, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x2d, x2d)


def vmem_tiles(n: int, rows: int, *, block_n: int = 256,
               block_t: int = 512, dtype="float32") -> list:
    """Static per-grid-step VMEM tile inventory (see paged_attention
    .vmem_tiles for the convention) — mirrors ``gram_accumulate``'s
    BlockSpecs above; consumed by repro.analysis.pallas_lint."""
    bn = min(block_n, n)
    bt = min(block_t, rows)
    return [
        {"name": "x_i", "shape": (bt, bn), "dtype": dtype, "buffers": 2},
        {"name": "x_j", "shape": (bt, bn), "dtype": dtype, "buffers": 2},
        {"name": "gram", "shape": (bn, bn), "dtype": "float32",
         "buffers": 2},
    ]
