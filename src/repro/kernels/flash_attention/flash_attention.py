"""Pallas TPU kernel: causal flash attention (GQA), 32k-prefill hot-spot.

Standard flash-attention-2 schedule adapted to TPU/Pallas:
  grid = (batch*kv_head, q_blocks, kv_blocks) with the kv axis innermost
  (sequential revisits of the same output block carry the online-softmax
  accumulators in VMEM scratch).  GQA: all G query heads of one KV head are
  processed together, so K/V tiles stream from HBM once per q block, and the
  (G*bq, bk) score tile keeps the MXU fed even for kv-light archs
  (chatglm3: G=16).

Causality: kv blocks strictly above the diagonal are skipped via
pl.when (no FLOPs, no HBM traffic beyond the prefetch); the diagonal block
applies the triangular mask.

This kernel is the TPU twin of models/attention.chunked_causal_attention
(the jnp path the dry-run lowers); tests sweep shapes/dtypes against it in
interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, bq, bk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal skip: this kv block starts after the last query of the block.
    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _():
        q = q_ref[0, 0]  # (G*bq, hd)
        k = k_ref[0]  # (bk, hd)
        v = v_ref[0]  # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        # Triangular mask on the diagonal block (and partial overlaps).
        g_bq = q.shape[0]
        g = g_bq // bq
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (g, bq), 1).reshape(g_bq)
        k_pos = kj * bk + jax.lax.iota(jnp.int32, bk)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd) -> (B, S, Hq, hd), causal.

    S must divide by the block sizes (ops.py pads).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    bq = min(block_q, s)
    bk = min(block_k, s)

    # Layout: fold (B, Hkv) into the grid's major axis; queries grouped.
    # q -> (B*Hkv, nq, G*bq, hd): group dim g varies fastest within a tile.
    qg = q.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,S,hd)
    qg = qg.reshape(b * hkv, g, s, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    nq = s // bq
    nk = s // bk

    # Tile q as (bh, nq, G*bq, hd) by interleaving: block (g, bq) flattened.
    qg = qg.transpose(0, 2, 1, 3).reshape(b * hkv, nq, bq, g, hd)
    qg = qg.transpose(0, 1, 3, 2, 4).reshape(b * hkv, nq, g * bq, hd)

    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g * bq, hd), lambda bh, qi, kj: (bh, qi, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, kj: (bh, kj, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * bq, hd), lambda bh, qi, kj: (bh, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, nq, g * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, hd), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    # Undo the tiling: (bh, nq, g*bq, hd) -> (B, S, Hq, hd).
    out = out.reshape(b * hkv, nq, g, bq, hd).transpose(0, 2, 1, 3, 4)
    out = out.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, s, hq, hd)


def vmem_tiles(seq: int, num_q_heads: int, num_kv_heads: int,
               head_dim: int, *, block_q: int = 256, block_k: int = 256,
               dtype="float32") -> list:
    """Static per-grid-step VMEM tile inventory (see paged_attention
    .vmem_tiles for the convention) — mirrors ``flash_attention``'s
    BlockSpecs/scratch above; consumed by repro.analysis.pallas_lint."""
    g = max(1, num_q_heads // max(1, num_kv_heads))
    bq = min(block_q, seq)
    bk = min(block_k, seq)
    return [
        {"name": "q", "shape": (1, 1, g * bq, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "k", "shape": (1, bk, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "v", "shape": (1, bk, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "out", "shape": (1, 1, g * bq, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "acc", "shape": (g * bq, head_dim), "dtype": "float32",
         "buffers": 1},
        {"name": "m_l", "shape": (2, g * bq, 1), "dtype": "float32",
         "buffers": 1},
    ]
