"""jit'd wrapper: padding + backend dispatch for flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _kernel_call
from .ref import flash_attention_ref


def flash_attention(q, k, v, block_q: int = 256, block_k: int = 256,
                    interpret: bool = False, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if not use_kernel:
        return flash_attention_ref(q, k, v)
    s = q.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    pad = (-s) % max(bq, bk)
    if pad:
        widths = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    out = _kernel_call(q, k, v, block_q=bq, block_k=bk, interpret=interpret)
    return out[:, : s] if pad else out
