"""Pure-jnp oracle: naive causal GQA attention (fp32 softmax)."""

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd)
