"""Pallas TPU kernel: paged-attention decode over a block-pool KV cache.

vLLM-style serving memory layout: K/V live in a shared pool of fixed-size
blocks (num_blocks, block_size, Hkv, hd) and each batch row names its blocks
through a block-table row.  Decode attends one query token per row over that
row's logical prefix, so the hot loop is pure HBM traffic — the kernel's job
is to stream exactly the live pages and nothing else (the dense-slab path
reads the full (max_batch, max_len) slab every step regardless of occupancy).

Schedule: grid = (batch,); the block table and per-row lengths ride scalar
prefetch (SMEM) so the page loop can compute DMA source indices before any
data lands.  Pools stay HBM-resident (memory_space=ANY); each iteration
async-copies one (block_size, Hkv, hd) page (plus its (block_size, Hkv)
dequant scales for int8 pools) into VMEM, accumulates online-softmax state
in fp32, and stops after ceil(length / block_size) pages — freed or
never-allocated tail blocks are never touched.

All Hkv heads of a row are processed per page so one DMA feeds the whole
(Hkv, G, block_size) score tile.  The (G, block_size) per-head tile is small
for GQA decode; this kernel targets correctness + page-exact HBM traffic
first (see ops.py for the dispatch contract; tests drive interpret mode).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, kp_ref, vp_ref, *rest, block_size, scale, quant):
    if quant:
        (ksp_ref, vsp_ref, o_ref, k_buf, v_buf, ks_buf, vs_buf,
         sem_k, sem_v, sem_ks, sem_vs) = rest
    else:
        o_ref, k_buf, v_buf, sem_k, sem_v = rest
    i = pl.program_id(0)
    bs = block_size
    length = len_ref[i]
    q = q_ref[0].astype(jnp.float32)  # (Hkv, G, hd)
    hkv, g, hd = q.shape
    n_pages = (length + bs - 1) // bs

    def body(p, carry):
        acc, m, l = carry
        page = jnp.maximum(bt_ref[i, p], 0)  # clamp freed rows' -1 sentinels
        ck = pltpu.make_async_copy(kp_ref.at[page], k_buf, sem_k)
        cv = pltpu.make_async_copy(vp_ref.at[page], v_buf, sem_v)
        ck.start()
        cv.start()
        if quant:
            cks = pltpu.make_async_copy(ksp_ref.at[page], ks_buf, sem_ks)
            cvs = pltpu.make_async_copy(vsp_ref.at[page], vs_buf, sem_vs)
            cks.start()
            cvs.start()
        ck.wait()
        cv.wait()
        k = k_buf[...].astype(jnp.float32)  # (bs, Hkv, hd)
        v = v_buf[...].astype(jnp.float32)
        if quant:
            cks.wait()
            cvs.wait()
            k = k * ks_buf[...][..., None]
            v = v * vs_buf[...][..., None]
        s = jnp.einsum("kgd,tkd->kgt", q, k, preferred_element_type=jnp.float32)
        s = s * scale
        pos = p * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pexp, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "kgt,tkd->kgd", pexp, v, preferred_element_type=jnp.float32
        )
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((hkv, g, hd), jnp.float32)
    m0 = jnp.full((hkv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((hkv, g, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_pages, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "interpret")
)
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, hd); pools (N, bs, Hkv, hd); block_tables (B, M) int32;
    lengths (B,) valid tokens per row (cache_len + 1).  Returns (B, Hq, hd).
    """
    b, hq, hd = q.shape
    _, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    quant = k_scales is not None

    qg = q.reshape(b, hkv, g, hd)  # head h = kv * G + gi, matching _gqa layout
    kernel = functools.partial(_kernel, block_size=bs, scale=scale, quant=quant)
    in_specs = [
        pl.BlockSpec((1, hkv, g, hd), lambda i, bt, ln: (i, 0, 0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    scratch = [
        pltpu.VMEM((bs, hkv, hd), k_pages.dtype),
        pltpu.VMEM((bs, hkv, hd), v_pages.dtype),
    ]
    operands = [block_tables, lengths, qg, k_pages, v_pages]
    if quant:
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ]
        scratch += [
            pltpu.VMEM((bs, hkv), jnp.float32),
            pltpu.VMEM((bs, hkv), jnp.float32),
        ]
        operands += [k_scales, v_scales]
    scratch += [pltpu.SemaphoreType.DMA] * (4 if quant else 2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, hkv, g, hd), lambda i, bt, ln: (i, 0, 0, 0)),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, hq, hd)
