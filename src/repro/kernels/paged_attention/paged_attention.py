"""Pallas TPU kernel: paged-attention decode over a block-pool KV cache.

vLLM-style serving memory layout: K/V live in a shared pool of fixed-size
blocks (num_blocks, block_size, Hkv, hd) and each batch row names its blocks
through a block-table row.  Decode attends one query token per row over that
row's logical prefix, so the hot loop is pure HBM traffic — the kernel's job
is to stream exactly the live pages and nothing else (the dense-slab path
reads the full (max_batch, max_len) slab every step regardless of occupancy).

Schedule — ROW-PACKED and DOUBLE-BUFFERED:

  * grid = (ceil(B / R),): each grid step processes a PACK of R decode rows
    (``rows_per_pack``).  A lone (G, block_size) score tile badly underfills
    the MXU for small GQA groups (G = Hq/Hkv is 1-4 for the archs served
    here); packing R rows turns every per-kv-head matmul into
    (R*G, hd) @ (hd, R*block_size) — R× more sublanes AND R× more lanes per
    MXU pass.  The cross-row score quadrants are junk by construction and
    are masked to -inf together with the per-row length mask, so the online
    softmax over the packed key axis reduces to exactly the per-row result
    (masked terms contribute zero weight).
  * The block table and per-row lengths ride scalar prefetch (SMEM) so page
    DMA source indices are known before any data lands.  Pools stay
    HBM-resident (memory_space=ANY); each pack iteration streams one
    (block_size, Hkv, hd) page PER PACKED ROW (plus (block_size, Hkv)
    dequant scales for int8 pools) into VMEM.
  * Page DMAs are DOUBLE-BUFFERED: two VMEM slots per operand, the copies
    for page p+1 start before the pack multiplies page p, so the next pages
    stream while the MXU works the current tile.
  * The page loop runs to the LONGEST packed row's page count; shorter
    rows' extra pages are fetched from a clamped block id and masked — the
    cost of packing, proportional to the length spread within a pack, is
    traded against the R× MXU fill (the serving decode roots zero dead
    rows' lengths so a retired slot never drags its pack; length-sorted
    packing for live rows is a queued follow-up).  Freed or never-
    allocated tail blocks beyond every packed row's length are never
    touched.

The jnp oracle in ref.py mirrors this packed layout (ragged last pack,
cross-row masking, int8 dequant inside the packed tile) so CPU tests pin
the kernel's tiling math, not just the attention result.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def default_rows_per_pack(batch: int, group: int) -> int:
    """Pack enough rows that the score tile's query dim (R*G) reaches the
    8-sublane fp32 tile, without padding tiny batches past themselves."""
    r = max(1, 8 // max(group, 1))
    return max(1, min(r, batch, 8))


def _kernel(bt_ref, len_ref, q_ref, kp_ref, vp_ref, *rest, block_size,
            scale, quant, rows_per_pack, max_blocks):
    if quant:
        (ksp_ref, vsp_ref, o_ref, k_buf, v_buf, ks_buf, vs_buf,
         sem_k, sem_v, sem_ks, sem_vs) = rest
    else:
        o_ref, k_buf, v_buf, sem_k, sem_v = rest
    bs = block_size
    r_pack = rows_per_pack
    i = pl.program_id(0)
    r0 = i * r_pack

    q = q_ref[...].astype(jnp.float32)  # (R, Hkv, G, hd)
    _, hkv, g, hd = q.shape
    # (Hkv, R*G, hd): kv-head-major so each head's packed queries multiply
    # that head's packed keys in one (R*G, hd) @ (hd, R*bs) MXU pass.
    qh = jnp.transpose(q, (1, 0, 2, 3)).reshape(hkv, r_pack * g, hd)

    lens = jnp.stack([len_ref[r0 + r] for r in range(r_pack)])  # (R,)
    n_pages = (jnp.max(lens) + bs - 1) // bs  # pack loop bound

    def dma(buf, pool_ref, sem, slot, r, page):
        return pltpu.make_async_copy(pool_ref.at[page], buf.at[slot, r],
                                     sem.at[slot, r])

    def start_pages(slot, p):
        pp = jnp.minimum(p, max_blocks - 1)
        for r in range(r_pack):
            # Clamp freed rows' -1 sentinels (and short rows' exhausted
            # tables) to block 0: the fetch is junk the mask hides.
            page = jnp.maximum(bt_ref[r0 + r, pp], 0)
            dma(k_buf, kp_ref, sem_k, slot, r, page).start()
            dma(v_buf, vp_ref, sem_v, slot, r, page).start()
            if quant:
                dma(ks_buf, ksp_ref, sem_ks, slot, r, page).start()
                dma(vs_buf, vsp_ref, sem_vs, slot, r, page).start()

    def wait_pages(slot, p):
        pp = jnp.minimum(p, max_blocks - 1)
        for r in range(r_pack):
            page = jnp.maximum(bt_ref[r0 + r, pp], 0)
            dma(k_buf, kp_ref, sem_k, slot, r, page).wait()
            dma(v_buf, vp_ref, sem_v, slot, r, page).wait()
            if quant:
                dma(ks_buf, ksp_ref, sem_ks, slot, r, page).wait()
                dma(vs_buf, vsp_ref, sem_vs, slot, r, page).wait()

    # Masks of the packed score tile: query n belongs to pack row n // G,
    # key column m to pack row m // bs — only the block diagonal is real.
    # Per-column row lengths are laid out by broadcast (no vector gather).
    rq = jax.lax.broadcasted_iota(jnp.int32, (r_pack * g, 1), 0) // g
    rc = jax.lax.broadcasted_iota(jnp.int32, (1, r_pack * bs), 1) // bs
    same_row = rq == rc                                    # (R*G, R*bs)
    key_off = jax.lax.broadcasted_iota(jnp.int32, (1, r_pack * bs), 1) % bs
    len_cols = jnp.broadcast_to(
        lens[:, None], (r_pack, bs)
    ).reshape(1, r_pack * bs)

    @pl.when(n_pages > 0)
    def _warmup():
        start_pages(0, 0)

    def body(p, carry):
        acc, m, l = carry
        slot = jax.lax.rem(p, 2)

        @pl.when(p + 1 < n_pages)
        def _prefetch():
            start_pages(jax.lax.rem(p + 1, 2), p + 1)

        wait_pages(slot, p)
        k = k_buf[slot].astype(jnp.float32)  # (R, bs, Hkv, hd)
        v = v_buf[slot].astype(jnp.float32)
        if quant:
            k = k * ks_buf[slot][..., None]
            v = v * vs_buf[slot][..., None]
        # (Hkv, R*bs, hd): packed-key layout matching qh.
        kh = jnp.transpose(k, (2, 0, 1, 3)).reshape(hkv, r_pack * bs, hd)
        vh = jnp.transpose(v, (2, 0, 1, 3)).reshape(hkv, r_pack * bs, hd)
        s = jnp.einsum("knd,kmd->knm", qh, kh,
                       preferred_element_type=jnp.float32)
        s = s * scale
        pos = p * bs + key_off
        valid = jnp.logical_and(same_row, pos < len_cols)  # (R*G, R*bs)
        s = jnp.where(valid[None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pexp, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "knm,kmd->knd", pexp, vh, preferred_element_type=jnp.float32
        )
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((hkv, r_pack * g, hd), jnp.float32)
    m0 = jnp.full((hkv, r_pack * g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((hkv, r_pack * g, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_pages, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)  # (Hkv, R*G, hd)
    o_ref[...] = jnp.transpose(
        out.reshape(hkv, r_pack, g, hd), (1, 0, 2, 3)
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "interpret", "rows_per_pack")
)
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
    rows_per_pack: int | None = None,
) -> jax.Array:
    """q: (B, Hq, hd); pools (N, bs, Hkv, hd); block_tables (B, M) int32;
    lengths (B,) valid tokens per row (cache_len + 1).  Returns (B, Hq, hd).

    ``rows_per_pack=None`` picks R so the packed score tile's query dim
    reaches the 8-sublane tile (R = 8 // G, clamped to [1, min(B, 8)]).
    Ragged batches are padded with length-0 rows to a whole pack and
    sliced back — padding never DMAs past page 0 of block 0.
    """
    b, hq, hd = q.shape
    _, bs, hkv, _ = k_pages.shape
    m = block_tables.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    quant = k_scales is not None
    r_pack = (default_rows_per_pack(b, g) if rows_per_pack is None
              else max(1, rows_per_pack))

    b_pad = -(-b // r_pack) * r_pack
    if b_pad != b:
        pad = b_pad - b
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        block_tables = jnp.pad(block_tables, ((0, pad), (0, 0)),
                               constant_values=-1)
        lengths = jnp.pad(lengths, (0, pad))  # length 0: fully masked

    qg = q.reshape(b_pad, hkv, g, hd)  # head h = kv*G + gi (_gqa layout)
    kernel = functools.partial(
        _kernel, block_size=bs, scale=scale, quant=quant,
        rows_per_pack=r_pack, max_blocks=m,
    )
    in_specs = [
        pl.BlockSpec((r_pack, hkv, g, hd), lambda i, bt, ln: (i, 0, 0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    scratch = [  # double-buffered (2 slots) per-row page tiles
        pltpu.VMEM((2, r_pack, bs, hkv, hd), k_pages.dtype),
        pltpu.VMEM((2, r_pack, bs, hkv, hd), v_pages.dtype),
    ]
    operands = [block_tables, lengths, qg, k_pages, v_pages]
    if quant:
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ]
        scratch += [
            pltpu.VMEM((2, r_pack, bs, hkv), jnp.float32),
            pltpu.VMEM((2, r_pack, bs, hkv), jnp.float32),
        ]
        operands += [k_scales, v_scales]
    scratch += [pltpu.SemaphoreType.DMA((2, r_pack))] * (4 if quant else 2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b_pad // r_pack,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((r_pack, hkv, g, hd),
                               lambda i, bt, ln: (i, 0, 0, 0)),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_pad, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out[:b].reshape(b, hq, hd)


def vmem_tiles(batch: int, num_q_heads: int, num_kv_heads: int,
               head_dim: int, block_size: int, *, dtype="float32",
               kv_dtype=None, quant: bool = False,
               rows_per_pack: int | None = None) -> list:
    """Static per-grid-step VMEM tile inventory for the packed decode
    kernel — one dict per resident buffer, mirroring the BlockSpecs /
    scratch_shapes in ``paged_attention`` above (keep in lockstep).

    ``buffers`` counts Pallas's automatic double-buffering of streamed
    BlockSpec operands (x2); the page rings carry their 2 DMA slots in
    their own leading dim, so they count once.  Consumed by
    repro.analysis.pallas_lint."""
    g = max(1, num_q_heads // max(1, num_kv_heads))
    hkv = max(1, num_kv_heads)
    r = (default_rows_per_pack(batch, g) if rows_per_pack is None
         else max(1, rows_per_pack))
    kv = kv_dtype or ("int8" if quant else dtype)
    tiles = [
        {"name": "q", "shape": (r, hkv, g, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "out", "shape": (r, hkv, g, head_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "k_page_ring", "shape": (2, r, block_size, hkv, head_dim),
         "dtype": kv, "buffers": 1},
        {"name": "v_page_ring", "shape": (2, r, block_size, hkv, head_dim),
         "dtype": kv, "buffers": 1},
        # fp32 softmax accumulators carried across the page loop.
        {"name": "acc", "shape": (hkv, r * g, head_dim), "dtype": "float32",
         "buffers": 1},
        {"name": "m_l", "shape": (2, hkv, r * g, 1), "dtype": "float32",
         "buffers": 1},
    ]
    if quant:
        tiles += [
            {"name": "k_scale_ring", "shape": (2, r, block_size, hkv),
             "dtype": "float32", "buffers": 1},
            {"name": "v_scale_ring", "shape": (2, r, block_size, hkv),
             "dtype": "float32", "buffers": 1},
        ]
    return tiles
