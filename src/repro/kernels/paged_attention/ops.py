"""jit'd wrapper: backend dispatch for paged-attention decode.

The Pallas kernel streams exactly the live KV pages on TPU; every other
backend (and the dry-run lowering) uses the jnp gather oracle, which is also
the bit-reference the serving equivalence tests pin against the dense-slab
decode path.  ``interpret=True`` forces the kernel body through the Pallas
interpreter (correctness tests on CPU)."""

from __future__ import annotations

from typing import Optional

import jax

from .paged_attention import default_rows_per_pack  # noqa: F401 (re-export)
from .paged_attention import paged_attention as _kernel_call
from .ref import (  # noqa: F401 (re-export)
    gather_pages,
    paged_attention_packed_ref,
    paged_attention_ref,
)


def paged_attention(
    q,
    k_pages,
    v_pages,
    block_tables,
    lengths,
    k_scales=None,
    v_scales=None,
    scale: Optional[float] = None,
    interpret: bool = False,
    use_kernel: Optional[bool] = None,
    rows_per_pack: Optional[int] = None,
):
    """Public op; see ref.paged_attention_ref for the argument contract.

    ``use_kernel=None`` picks the Pallas kernel on TPU and the oracle
    elsewhere; pass True/False to force either side.  ``rows_per_pack``
    sets the kernel's decode-row packing (None = auto: fill the 8-sublane
    score tile, see paged_attention.default_rows_per_pack); the oracle
    path ignores it — packing is a tiling choice, not a math change."""
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if not use_kernel:
        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales, scale
        )
    return _kernel_call(
        q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales,
        scale=scale, interpret=interpret, rows_per_pack=rows_per_pack,
    )
