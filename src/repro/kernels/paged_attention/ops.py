"""jit'd wrapper: backend dispatch for paged-attention decode.

The Pallas kernel streams exactly the live KV pages on TPU; every other
backend (and the dry-run lowering) uses the jnp gather oracle, which is also
the bit-reference the serving equivalence tests pin against the dense-slab
decode path.  ``interpret=True`` forces the kernel body through the Pallas
interpreter (correctness tests on CPU)."""

from __future__ import annotations

from typing import Optional

import jax

from .paged_attention import paged_attention as _kernel_call
from .ref import gather_pages, paged_attention_ref  # noqa: F401 (re-export)


def paged_attention(
    q,
    k_pages,
    v_pages,
    block_tables,
    lengths,
    k_scales=None,
    v_scales=None,
    scale: Optional[float] = None,
    interpret: bool = False,
    use_kernel: Optional[bool] = None,
):
    """Public op; see ref.paged_attention_ref for the argument contract.

    ``use_kernel=None`` picks the Pallas kernel on TPU and the oracle
    elsewhere; pass True/False to force either side."""
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if not use_kernel:
        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales, scale
        )
    return _kernel_call(
        q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales,
        scale=scale, interpret=interpret,
    )
