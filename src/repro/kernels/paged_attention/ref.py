"""Pure-jnp oracle for paged-attention decode.

The KV cache lives in a shared block pool of shape (num_blocks, block_size,
Hkv, hd); each batch row owns an ordered list of physical block ids (its
block-table row), so logical position p of row b lives at
``pool[block_tables[b, p // bs], p % bs]``.  The oracle gathers every row's
pages into a dense (B, M*bs, Hkv, hd) view and runs the same masked-softmax
math as the dense-slab decode path — it is the CPU twin the serving engine
uses off-TPU and the reference the Pallas kernel is validated against.

Block-table entries < 0 mark unallocated tail blocks (gather clamps them to
block 0; the length mask hides whatever garbage that reads).

``paged_attention_packed_ref`` is the row-packed twin mirroring the Pallas
kernel's MXU tiling (packs of rows share one block-diagonal-masked score
tile); it computes the same attention and exists so CPU tests can pin the
packed layout's masking/ragged-pack/dequant math independently of the
kernel.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """pool (N, bs, ...), block_tables (B, M) -> dense view (B, M*bs, ...).

    Row-major over (logical block, offset): position p of row b lands at
    index p in the output.  Negative table entries are clamped to block 0;
    callers mask those positions by length."""
    g = pool[jnp.maximum(block_tables, 0)]  # (B, M, bs, ...)
    return g.reshape(g.shape[0], -1, *pool.shape[2:])


def paged_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token paged GQA decode attention.

    q: (B, Hq, hd) — the new token's query (already rope'd).
    k_pages/v_pages: (N, bs, Hkv, hd) block pools (int8 when quantized).
    k_scales/v_scales: (N, bs, Hkv) dequant scales for int8 pools.
    block_tables: (B, M) int32 physical block ids, -1 beyond the allocation.
    lengths: (B,) int32 valid token count per row (INCLUDING the token
      written this step, i.e. cache_len + 1).
    Returns (B, Hq, hd) in q.dtype.
    """
    b, hq, hd = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    k = gather_pages(k_pages, block_tables)  # (B, T, Hkv, hd)
    v = gather_pages(v_pages, block_tables)
    if k_scales is not None:
        k = (k.astype(jnp.float32) * gather_pages(k_scales, block_tables)[..., None]).astype(q.dtype)
        v = (v.astype(jnp.float32) * gather_pages(v_scales, block_tables)[..., None]).astype(q.dtype)

    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    t = k.shape[1]
    valid = jnp.arange(t)[None, :] < lengths[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v.dtype), v)
    return out.reshape(b, hq, hd).astype(q.dtype)


def paged_attention_packed_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    rows_per_pack: int = 4,
) -> jax.Array:
    """Row-packed twin of the oracle, mirroring the Pallas kernel's tiling:
    packs of R rows share one score tile whose key axis CONCATENATES the
    packed rows' pages, with the cross-row quadrants (and per-row length
    tails) masked to -inf so the softmax reduces to each row's own result.

    Same arguments and result as ``paged_attention_ref`` — the point of
    this twin is that CPU tests can pin the PACKED layout's math (ragged
    last pack, block-diagonal masking, int8 dequant inside the packed
    tile) against both the plain oracle and the kernel."""
    b, hq, hd = q.shape
    n, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    r_pack = max(1, rows_per_pack)

    b_pad = -(-b // r_pack) * r_pack
    if b_pad != b:
        pad = b_pad - b
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        block_tables = jnp.pad(block_tables, ((0, pad), (0, 0)),
                               constant_values=-1)
        lengths = jnp.pad(lengths, (0, pad))

    k = gather_pages(k_pages, block_tables)  # (B', T, Hkv, hd)
    v = gather_pages(v_pages, block_tables)
    if k_scales is not None:
        k = (k.astype(jnp.float32)
             * gather_pages(k_scales, block_tables)[..., None])
        v = (v.astype(jnp.float32)
             * gather_pages(v_scales, block_tables)[..., None])
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    t = k.shape[1]

    npk = b_pad // r_pack
    # (npk, Hkv, R*G, hd) packed queries; (npk, Hkv, R*T, hd) packed keys.
    qp = jnp.transpose(
        q.astype(jnp.float32).reshape(npk, r_pack, hkv, g, hd),
        (0, 2, 1, 3, 4),
    ).reshape(npk, hkv, r_pack * g, hd)
    kp = jnp.transpose(
        k.reshape(npk, r_pack, t, hkv, hd), (0, 3, 1, 2, 4)
    ).reshape(npk, hkv, r_pack * t, hd)
    vp = jnp.transpose(
        v.reshape(npk, r_pack, t, hkv, hd), (0, 3, 1, 2, 4)
    ).reshape(npk, hkv, r_pack * t, hd)

    s = jnp.einsum("pknd,pkmd->pknm", qp, kp,
                   preferred_element_type=jnp.float32) * scale
    rq = jnp.arange(r_pack * g)[:, None] // g          # query's pack row
    rc = jnp.arange(r_pack * t)[None, :] // t          # key's pack row
    pos = jnp.arange(r_pack * t)[None, :] % t          # key's logical pos
    len_rows = lengths.reshape(npk, r_pack)            # (npk, R)
    # Per-column lengths: column m belongs to pack row m // t.
    len_cols = jnp.repeat(len_rows, t, axis=1)         # (npk, R*T)
    valid = jnp.logical_and((rq == rc)[None], pos[None] < len_cols[:, None])
    s = jnp.where(valid[:, None], s, NEG_INF)          # (npk, Hkv, RG, RT)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("pknm,pkmd->pknd", p, vp)           # (npk, Hkv, R*G, hd)
    out = jnp.transpose(
        o.reshape(npk, hkv, r_pack, g, hd), (0, 2, 1, 3, 4)
    ).reshape(b_pad, hq, hd)
    return out[:b].astype(q.dtype)
