"""Pure-jnp scan oracle for the RWKV6 recurrence (matches models/rwkv6.py)."""

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """r/k/v/w: (BH, T, K); u: (BH, K) -> y (BH, T, K), fp32 math."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (BH, K)
        kv = k_t[:, :, None] * v_t[:, None, :]  # (BH, K, V)
        y = jnp.einsum("bk,bkv->bv", r_t, s + uf[:, :, None] * kv)
        s = w_t[:, :, None] * s + kv
        return s, y

    bh, t, kdim = r.shape
    s0 = jnp.zeros((bh, kdim, kdim), jnp.float32)
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
