"""Pallas TPU kernel: chunked RWKV6 time-mix recurrence.

The RWKV6 recurrence per head (K = V = head_dim, state S in R^{KxV}):

    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

A naive scan is O(T) sequential steps of rank-1 updates — the 512k-token
long-context hot-spot.  This kernel processes the sequence in chunks of L
tokens: the inter-chunk state is carried sequentially (grid minor axis),
while all intra-chunk work is dense matmul on the MXU:

    per chunk, with logcum_t = sum_{s<=t} log w_s (per channel):
      cross:  y_t += (r_t * exp(logcum_{t-1})) @ S
      intra:  y_t += sum_{s<t} [sum_k r_t[k] k_s[k] e^{logcum_{t-1}[k]
                                 - logcum_s[k]}] v_s     (strictly lower tri)
      bonus:  y_t += (r_t * u * k_t) @ v_t               (diagonal)
      state:  S   <- exp(logcum_L) * S
                     + sum_s (k_s * e^{logcum_L - logcum_s})^T v_s

All exponents are differences with s <= t, hence <= 0 after the chunk-local
rebase — no overflow for any decay magnitude (the scan reference and the
official CUDA kernel share this property; the (L, L, K) broadcast lives in
VMEM, so L is kept at 16-32).

TPU adaptation notes (DESIGN.md §3): the CUDA kernel assigns one warp per
(batch, head) and shuffles the rank-1 updates; here the chunk-dense form
turns ~L rank-1 updates into three (L,K)x(K,V)-class contractions that run
on the MXU, with the sequential dependency reduced from T steps to T/L.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # (L, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = jnp.log(jnp.maximum(w_ref[0].astype(jnp.float32), 1e-38))
    u = u_ref[0].astype(jnp.float32)  # (1, K) bonus

    logcum = jnp.cumsum(logw, axis=0)  # (L, K) inclusive
    logecum = logcum - logw  # exclusive (prod over s < t)

    s = s_ref[...]  # (K, V)

    # Cross-chunk: (L, K) @ (K, V)
    y = jnp.dot(r * jnp.exp(logecum), s, preferred_element_type=jnp.float32)

    # Intra-chunk: A[t, s] = sum_k r[t,k] k[s,k] exp(logecum[t,k] - logcum[s,k])
    lw = logecum[:, None, :] - logcum[None, :, :]  # (L, L, K), <= 0 for s < t
    ltri = jnp.tril(jnp.ones((r.shape[0], r.shape[0]), jnp.float32), k=-1)
    a = jnp.sum(
        r[:, None, :] * k[None, :, :] * jnp.exp(jnp.minimum(lw, 0.0)), axis=-1
    )
    y += jnp.dot(a * ltri, v, preferred_element_type=jnp.float32)

    # Diagonal bonus term.
    y += jnp.sum(r * u * k, axis=-1, keepdims=True) * v

    # State update.
    decay_all = jnp.exp(logcum[-1])  # (K,)
    carry = jnp.exp(logcum[-1][None, :] - logcum)  # (L, K), <= 1
    s_new = decay_all[:, None] * s + jnp.dot(
        (k * carry).T, v, preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    chunk: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """r/k/v/w: (BH, T, K); u: (BH, K).  Returns y: (BH, T, K).

    T must be divisible by chunk (ops.py pads).  The per-(batch*head)
    programs are the parallel grid axis; chunks are the sequential axis
    carrying the state scratch.
    """
    bh, t_len, kdim = r.shape
    l = min(chunk, t_len)
    grid = (bh, t_len // l)
    u3 = u[:, None, :]  # (BH, 1, K)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, kdim), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, l, kdim), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, l, kdim), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, l, kdim), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, 1, kdim), lambda b, t: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, kdim), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t_len, kdim), r.dtype),
        scratch_shapes=[pltpu.VMEM((kdim, kdim), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u3)


def vmem_tiles(t_len: int, k_dim: int, *, chunk: int = 16,
               dtype="float32") -> list:
    """Static per-grid-step VMEM tile inventory (see paged_attention
    .vmem_tiles for the convention) — mirrors ``rwkv6_chunked``'s
    BlockSpecs/scratch above; consumed by repro.analysis.pallas_lint."""
    l = min(chunk, t_len)
    tiles = [
        {"name": nm, "shape": (1, l, k_dim), "dtype": dtype, "buffers": 2}
        for nm in ("r", "k", "v", "w")
    ]
    tiles += [
        {"name": "u", "shape": (1, 1, k_dim), "dtype": dtype, "buffers": 2},
        {"name": "out", "shape": (1, l, k_dim), "dtype": dtype,
         "buffers": 2},
        {"name": "state", "shape": (k_dim, k_dim), "dtype": "float32",
         "buffers": 1},
    ]
    return tiles
