"""jit'd wrapper for the chunked RWKV6 kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import rwkv6_scan_ref
from .rwkv6 import rwkv6_chunked


def rwkv6_attention(r, k, v, w, u, chunk: int = 16, interpret: bool = False,
                    use_kernel: bool | None = None):
    """r/k/v/w: (BH, T, K); u: (BH, K) -> (BH, T, K)."""
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if not use_kernel:
        return rwkv6_scan_ref(r, k, v, w, u)
    t = r.shape[1]
    pad = (-t) % chunk
    if pad:
        widths = [(0, 0), (0, pad), (0, 0)]
        r = jnp.pad(r, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        w = jnp.pad(w, widths, constant_values=1.0)  # identity decay
    y = rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return y[:, : t] if pad else y
