"""jit'd wrapper: padding + dispatch (kernel on TPU, oracle elsewhere)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .nested_lowrank import (
    VMEM_LIMIT_BYTES,
    kernel_vmem_bytes,
    nested_lowrank_matmul as _kernel_call,
)
from .ref import nested_lowrank_matmul_ref


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# The kernel keeps x and both rank-k intermediates resident in VMEM, so it
# only pays off (and only fits the ~16 MB budget) for decode-shaped row
# counts; larger batches (prefill/train) stay on the XLA matmul path.
MAX_KERNEL_ROWS = 1024


def nested_lowrank_matmul(
    x, u, v, u2, v2, block_n: int = 256, interpret: bool = False,
    use_kernel: bool | None = None,
):
    """Public op.  On non-TPU backends (and under dry-run lowering) the
    pure-jnp oracle is used; interpret=True forces the kernel body through
    the Pallas interpreter (correctness tests).  ``use_kernel=None`` picks
    the kernel only for decode-shaped inputs (flattened rows <=
    MAX_KERNEL_ROWS) on TPU; pass True to force it regardless."""
    if use_kernel is None:
        rows = 1
        for s in x.shape[:-1]:
            rows *= s
        # Row gate AND a VMEM gate: the resident u/u2 tiles scale with the
        # decomposition rank, so a mildly-compressed wide layer (rank of
        # order d_model/2) overflows VMEM even at decode row counts —
        # those shapes stay on the XLA matmul path.
        use_kernel = (
            interpret
            or (jax.default_backend() == "tpu"
                and rows <= MAX_KERNEL_ROWS
                and kernel_vmem_bytes(
                    rows, x.shape[-1], v.shape[-1], u.shape[-1],
                    u2.shape[-1],
                    block_n=min(block_n, v.shape[-1]),
                    dtype=str(x.dtype)) <= VMEM_LIMIT_BYTES)
        )
    if not use_kernel:
        return nested_lowrank_matmul_ref(x, u, v, u2, v2)
    n = v.shape[-1]
    bn = min(block_n, n)
    v_p, pad_n = _pad_to(v, bn, -1)
    v2_p, _ = _pad_to(v2, bn, -1)
    y = _kernel_call(x, u, v_p, u2, v2_p, block_n=bn, interpret=interpret)
    if pad_n:
        y = y[..., : n]
    return y
