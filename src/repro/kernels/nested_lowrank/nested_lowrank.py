"""Pallas TPU kernel: fused nested low-rank matmul (paper Eq. 6).

Computes  y = (x @ u) @ v + (x @ u2) @ v2  in ONE pass over the factored
weights — the decode-time hot-spot of an NSVD-compressed model.

Why fuse (DESIGN.md §3/§4): at decode the batch of live rows is small
(M ≈ 64-512), so both GEMMs are memory-bound on weight traffic.  A naive
two-kernel schedule streams u, v, u2, v2 from HBM *and* round-trips the
rank-k intermediate through HBM.  This kernel tiles N (the output dim) on
the grid, keeps x and both rank-k intermediates resident in VMEM, streams
each weight tile exactly once, and accumulates both branches into the same
fp32 VMEM accumulator:

  grid over (N / bn):
    t  = x @ u        (M, k1)     computed once on the first grid step,
    t2 = x @ u2       (M, k2)      cached in VMEM scratch
    y[:, j] = t @ v[:, j] + t2 @ v2[:, j]

VMEM budget per step: M*K (x) + M*(k1+k2) (intermediates) + K*? ...
with M<=512, K<=16384, k<=1408, bn=256 everything sits well under 16 MB.
MXU alignment: block shapes padded to multiples of (8, 128) by BlockSpec;
ranks are budgeted to multiples of 128 by ratio.py when tpu_friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, u_ref, v_ref, u2_ref, v2_ref, y_ref, t_ref, t2_ref):
    """One grid step: j-th tile of the output dim."""
    j = pl.program_id(0)

    # First grid step computes the shared rank-k intermediates.
    @pl.when(j == 0)
    def _():
        x = x_ref[...]
        t_ref[...] = jnp.dot(
            x, u_ref[...], preferred_element_type=jnp.float32
        )
        t2_ref[...] = jnp.dot(
            x, u2_ref[...], preferred_element_type=jnp.float32
        )

    t = t_ref[...].astype(v_ref.dtype)
    t2 = t2_ref[...].astype(v2_ref.dtype)
    acc = jnp.dot(t, v_ref[...], preferred_element_type=jnp.float32)
    acc += jnp.dot(t2, v2_ref[...], preferred_element_type=jnp.float32)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def nested_lowrank_matmul(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    u2: jax.Array,
    v2: jax.Array,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K); u: (K, k1); v: (k1, N); u2: (K, k2); v2: (k2, N) -> (M, N).

    Leading batch dims of x are flattened.  N must be divisible by block_n
    (callers pad; ops.py handles it).
    """
    orig_shape = x.shape
    m = 1
    for s in orig_shape[:-1]:
        m *= s
    k_in = x.shape[-1]
    x2d = x.reshape(m, k_in)
    n = v.shape[-1]
    k1 = u.shape[-1]
    k2 = u2.shape[-1]
    bn = min(block_n, n)
    grid = (n // bn,)

    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k_in), lambda j: (0, 0)),  # x resident
            pl.BlockSpec((k_in, k1), lambda j: (0, 0)),  # u resident
            pl.BlockSpec((k1, bn), lambda j: (0, j)),  # v streamed by tile
            pl.BlockSpec((k_in, k2), lambda j: (0, 0)),  # u2 resident
            pl.BlockSpec((k2, bn), lambda j: (0, j)),  # v2 streamed by tile
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((m, k1), jnp.float32),
            pltpu.VMEM((m, k2), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, u, v, u2, v2)
    return y.reshape(*orig_shape[:-1], n)


def vmem_tiles(m: int, k_in: int, n: int, k1: int, k2: int, *,
               block_n: int = 256, dtype="float32") -> list:
    """Static per-grid-step VMEM tile inventory (see paged_attention
    .vmem_tiles for the convention) — mirrors ``nested_lowrank_matmul``'s
    BlockSpecs/scratch above; consumed by repro.analysis.pallas_lint."""
    bn = min(block_n, n)
    # x/u/u2 have CONSTANT index maps (resident across the grid, fetched
    # once); only the column-streamed v/v2/y tiles pay the x2 pipeline
    # double-buffer.
    return [
        {"name": "x", "shape": (m, k_in), "dtype": dtype, "buffers": 1},
        {"name": "u", "shape": (k_in, k1), "dtype": dtype, "buffers": 1},
        {"name": "v", "shape": (k1, bn), "dtype": dtype, "buffers": 2},
        {"name": "u2", "shape": (k_in, k2), "dtype": dtype, "buffers": 1},
        {"name": "v2", "shape": (k2, bn), "dtype": dtype, "buffers": 2},
        {"name": "y", "shape": (m, bn), "dtype": dtype, "buffers": 2},
        {"name": "t1", "shape": (m, k1), "dtype": "float32", "buffers": 1},
        {"name": "t2", "shape": (m, k2), "dtype": "float32", "buffers": 1},
    ]


VMEM_LIMIT_BYTES = int(16 * 2**20 * 0.9)  # per-core VMEM less compiler slack


def kernel_vmem_bytes(m: int, k_in: int, n: int, k1: int, k2: int, *,
                      block_n: int = 256, dtype="bfloat16") -> int:
    """Padded VMEM bytes one grid step needs — the dispatch gate in ops.py
    compares this against ``VMEM_LIMIT_BYTES`` (resident u/u2 factors grow
    with rank, so large-rank decompositions must stay on the XLA path)."""
    import numpy as np

    total = 0
    for t in vmem_tiles(m, k_in, n, k1, k2, block_n=block_n, dtype=dtype):
        item = np.dtype(str(t["dtype"])).itemsize
        sub = {8: 8, 4: 8, 2: 16, 1: 32}[item]
        shape = tuple(t["shape"])
        if len(shape) == 1:
            shape = (1,) + shape
        pad = shape[:-2] + (-(-shape[-2] // sub) * sub,
                            -(-shape[-1] // 128) * 128)
        total += int(np.prod(pad, dtype=np.int64)) * item * t["buffers"]
    return total
