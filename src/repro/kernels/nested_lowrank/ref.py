"""Pure-jnp oracle for the nested low-rank matmul."""

import jax.numpy as jnp


def nested_lowrank_matmul_ref(x, u, v, u2, v2):
    y = jnp.matmul(jnp.matmul(x, u), v)
    return y + jnp.matmul(jnp.matmul(x, u2), v2)
