"""Serving fault-tolerance tests: seeded FaultPlan injection across every
fault kind, with the acceptance bar that NON-TARGETED requests' greedy
streams stay bit-identical to the fault-free run (both cache layouts,
pipeline depths 1-2), targeted requests finish with a structured
error/retry, and the fault accounting (plan fired log vs engine
counters) reconciles exactly."""

import json
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.serving.faults import (
    FAULT_KINDS,
    FaultPlan,
    FaultPolicy,
    FaultSpec,
    ServingFault,
    ServingFaultHandler,
)
from repro.serving.scheduler import SchedulerConfig

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-faults", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _prompts(seed, n, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 200, size=int(rng.integers(lo, hi)))
            for _ in range(n)]


def _run(model, params, prompts, max_new=12, faults=None, policy=None,
         **kw):
    """Run one engine over ``prompts``; returns (streams-in-order, eng)."""
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    eng = ServingEngine(model, params, faults=faults, fault_policy=policy,
                        **kw)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run()
    return [out.get(u) for u in uids], eng


# --------------------------------------------------------- plan units


class TestFaultSpec:
    def test_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("meteor_strike")

    def test_poison_requires_uid(self):
        with pytest.raises(ValueError, match="uid"):
            FaultSpec("poison_logits")

    @pytest.mark.parametrize("kw", [{"step": -1}, {"delay_s": -0.1}])
    def test_rejects_negative(self, kw):
        with pytest.raises(ValueError):
            FaultSpec("straggler", **kw)

    def test_kind_table_covers_spec_kinds(self):
        assert set(FAULT_KINDS) == {"poison_logits", "alloc_fail",
                                    "swap_corrupt", "straggler",
                                    "draft_kill"}


class TestFaultPlan:
    def test_take_gates_on_step_and_fires_once(self):
        plan = FaultPlan([FaultSpec("alloc_fail", step=3)])
        assert plan.take("alloc_fail", 2) is None
        sp = plan.take("alloc_fail", 3)
        assert sp is not None and sp.step == 3
        assert plan.take("alloc_fail", 4) is None   # fire-once
        assert plan.counts() == {"alloc_fail": 1}
        assert plan.outstanding() == []

    def test_take_uid_matching(self):
        plan = FaultPlan([FaultSpec("swap_corrupt", uid=7)])
        # uid-targeted spec never fires for another uid or for no uid
        assert plan.take("swap_corrupt", 0, uid=3) is None
        assert plan.take("swap_corrupt", 0, uid=None) is None
        assert plan.take("swap_corrupt", 0, uid=7) is not None
        # untargeted spec matches any uid
        plan = FaultPlan([FaultSpec("swap_corrupt")])
        assert plan.take("swap_corrupt", 0, uid=123) is not None

    def test_outstanding_reports_unfired(self):
        plan = FaultPlan([FaultSpec("straggler", step=999),
                          FaultSpec("alloc_fail")])
        plan.take("alloc_fail", 0)
        out = plan.outstanding()
        assert len(out) == 1 and out[0].kind == "straggler"

    def test_json_round_trip(self, tmp_path):
        plan = FaultPlan([FaultSpec("poison_logits", step=2, uid=1),
                          FaultSpec("straggler", step=4, delay_s=0.5)])
        path = tmp_path / "plan.json"
        path.write_text(plan.to_json())
        back = FaultPlan.from_json(str(path))
        assert back.specs == plan.specs

    def test_from_json_accepts_sparse_specs(self, tmp_path):
        path = tmp_path / "plan.json"
        path.write_text(json.dumps(
            {"faults": [{"kind": "alloc_fail"},
                        {"kind": "straggler", "step": 3}]}))
        plan = FaultPlan.from_json(str(path))
        assert len(plan) == 2 and plan.specs[1].step == 3


class TestFaultPolicy:
    def test_backoff_is_capped_exponential(self):
        pol = FaultPolicy(max_retries=8, retry_backoff_steps=4,
                          retry_backoff_cap=64)
        assert [pol.backoff(a) for a in (1, 2, 3, 4, 5, 6)] == \
            [4, 8, 16, 32, 64, 64]

    @pytest.mark.parametrize("kw", [
        {"max_retries": -1},
        {"retry_backoff_steps": 0},
        {"retry_backoff_cap": 0},
    ])
    def test_rejects_bad_knobs(self, kw):
        with pytest.raises(ValueError):
            FaultPolicy(**kw)

    def test_handler_retries_then_quarantines(self):
        class _R:
            retries = 0

        h = ServingFaultHandler(FaultPolicy(max_retries=2))
        r = _R()
        assert h.disposition(r) == ("retry", 4)
        assert h.disposition(r) == ("retry", 8)
        assert h.disposition(r) == ("quarantine", 0)
        assert (h.retried, h.quarantined) == (2, 1)
        assert r.retries == 2


# ----------------------------------------------- poisoned-step isolation


class TestPoisonIsolation:
    @pytest.mark.parametrize("paged", [False, True])
    @pytest.mark.parametrize("depth", [1, 2])
    def test_quarantine_isolates_healthy_streams(self, tiny_lm, paged,
                                                 depth):
        """A poisoned request retires with finish_reason='error'; every
        other request's greedy stream is bit-identical to the fault-free
        run — both cache layouts, pipeline depths 1 and 2."""
        model, params = tiny_lm
        prompts = _prompts(20, 3)
        base, _ = _run(model, params, prompts, paged=paged,
                       pipeline_depth=depth)
        plan = FaultPlan([FaultSpec("poison_logits", step=2, uid=1)])
        got, eng = _run(model, params, prompts, paged=paged,
                        pipeline_depth=depth, faults=plan)
        assert eng.finished_requests[1].finish_reason == "error"
        for uid in (0, 2):
            assert got[uid] == base[uid], uid
            assert eng.finished_requests[uid].finish_reason == "stop"
        fs = eng.fault_stats()
        assert fs["injected"] == {"poison_logits": 1}
        assert fs["quarantined"] == 1 and fs["retried"] == 0
        assert plan.outstanding() == []

    def test_retry_recovers_full_stream(self, tiny_lm):
        """With a retry budget the poisoned request reprefills after a
        backoff park and its final stream matches the fault-free run."""
        model, params = tiny_lm
        prompts = _prompts(21, 3)
        base, _ = _run(model, params, prompts, paged=True)
        plan = FaultPlan([FaultSpec("poison_logits", step=2, uid=1)])
        got, eng = _run(model, params, prompts, paged=True, faults=plan,
                        policy=FaultPolicy(max_retries=2,
                                           retry_backoff_steps=2))
        assert got == base
        fs = eng.fault_stats()
        assert fs["retried"] == 1 and fs["quarantined"] == 0
        assert eng.finished_requests[1].finish_reason == "stop"
        assert eng.finished_requests[1].retries == 1


# ---------------------------------------------- allocator + swap faults


class TestAllocAndSwapFaults:
    def test_alloc_fail_is_absorbed(self, tiny_lm):
        """Failed reservations back off and retry; streams and finish
        reasons are unchanged."""
        model, params = tiny_lm
        prompts = _prompts(22, 4)
        kw = dict(paged=True, block_size=8, num_blocks=24)
        base, _ = _run(model, params, prompts, **kw)
        plan = FaultPlan([FaultSpec("alloc_fail", step=0),
                          FaultSpec("alloc_fail", step=2),
                          FaultSpec("alloc_fail", step=4)])
        got, eng = _run(model, params, prompts, faults=plan, **kw)
        assert got == base
        assert all(r.finish_reason == "stop"
                   for r in eng.finished_requests.values())
        assert eng.fault_stats()["injected"]["alloc_fail"] == 3

    def test_swap_corrupt_falls_back_to_reprefill(self, tiny_lm):
        """A corrupted swap payload fails its checksum at resume and the
        engine reprefills from host context instead of scattering the
        poisoned blocks back — streams still match the uncontended run."""
        model, params = tiny_lm
        prompts = _prompts(23, 5)
        kw = dict(paged=True, block_size=8, num_blocks=8, max_new=16,
                  sched_config=SchedulerConfig(admission="on_demand",
                                               preempt=True,
                                               resume="swap"))
        # Uncontended baseline: same workload, pool covers worst case.
        base, b_eng = _run(model, params, prompts, paged=True,
                           block_size=8, num_blocks=32, max_new=16)
        assert b_eng.scheduler_stats()["preempt_count"] == 0
        plan = FaultPlan([FaultSpec("swap_corrupt")])
        got, eng = _run(model, params, prompts, faults=plan, **kw)
        assert eng.scheduler_stats()["preempt_count"] > 0
        assert eng.fault_stats()["swap_fallbacks"] == 1
        assert eng.fault_stats()["injected"]["swap_corrupt"] == 1
        assert got == base


# ------------------------------------------- stragglers + hard timeouts


class TestStragglerAndTimeout:
    def test_straggler_flagged_without_timeout(self, tiny_lm):
        """The watchdog classifies against a median of >=8 clean steps, so
        the stall is injected late enough for that baseline to exist."""
        model, params = tiny_lm
        prompts = _prompts(24, 2)
        base, _ = _run(model, params, prompts, paged=True, max_new=16)
        plan = FaultPlan([FaultSpec("straggler", step=11, delay_s=0.5)])
        got, eng = _run(model, params, prompts, paged=True, max_new=16,
                        faults=plan)
        assert got == base
        fs = eng.fault_stats()
        assert fs["injected"]["straggler"] == 1
        assert fs["straggler_slow"] >= 1

    def test_step_timeout_raises_structured_fault(self, tiny_lm):
        """Exceeding the hard step budget raises ServingFault with a
        JSON-serializable engine snapshot.  The engine is warmed on its
        own first request so jit compilation (seconds on CPU) does not
        trip the budget before the injected stall does."""
        import dataclasses

        model, params = tiny_lm
        plan = FaultPlan([FaultSpec("straggler", step=20, delay_s=0.6)])
        eng = ServingEngine(
            model, params, max_batch=1, max_len=64, paged=True,
            faults=plan, fault_policy=FaultPolicy())
        eng.submit(_prompts(25, 1)[0], max_new_tokens=4)
        eng.run()                                       # warm: steps ~5
        # Arm the hard budget only once jit caches are hot, as a
        # deployment would — compile steps are expected-slow.
        eng._fault_policy = dataclasses.replace(
            eng._fault_policy, step_timeout_s=0.5)
        eng.submit(_prompts(26, 1)[0], max_new_tokens=32)
        with pytest.raises(ServingFault) as ei:
            eng.run()
        assert ei.value.kind == "step_timeout"
        snap = ei.value.snapshot
        assert snap["step"] >= 20 and snap["pipeline_depth"] >= 1
        json.dumps(snap)                                # post-mortem-able


# ------------------------------------- deadlines, cancel, drain, close


class TestDeadlinesAndLifecycle:
    def test_deadline_shed(self, tiny_lm):
        """A queued request whose deadline lapses before admission is
        shed with finish_reason='deadline'; survivors are unaffected."""
        model, params = tiny_lm
        prompts = _prompts(27, 2)
        base, _ = _run(model, params, [prompts[0]], max_batch=1,
                       max_new=8)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            fault_policy=FaultPolicy())
        u0 = eng.submit(prompts[0], max_new_tokens=8)
        u1 = eng.submit(prompts[1], max_new_tokens=8, deadline_s=1e-4)
        time.sleep(0.01)
        out = eng.run()
        assert out[u0] == base[0]
        assert u1 not in out or out[u1] == []
        assert eng.finished_requests[u1].finish_reason == "deadline"
        assert eng.fault_stats()["shed"] == 1

    def test_submit_rejects_bad_deadline(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        with pytest.raises(ValueError):
            eng.submit(np.arange(2, 6), deadline_s=0.0)

    def test_cancel_queued(self, tiny_lm):
        model, params = tiny_lm
        prompts = _prompts(28, 2)
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        u0 = eng.submit(prompts[0], max_new_tokens=8)
        u1 = eng.submit(prompts[1], max_new_tokens=8)
        assert eng.cancel(u1) is True
        assert eng.cancel(u1) is False            # already gone
        assert eng.cancel(999) is False           # unknown uid
        out = eng.run()
        assert out[u1] == []                      # reported, empty stream
        assert eng.finished_requests[u1].finish_reason == "cancelled"
        assert eng.fault_stats()["cancelled"] == 1
        assert len(out[u0]) == 8

    def test_request_drain_sheds_backlog(self, tiny_lm):
        model, params = tiny_lm
        prompts = _prompts(29, 3)
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.request_drain()
        out = eng.run()
        # Only already-admittable work proceeds; the backlog sheds (and
        # is still reported in the output map, with an empty stream).
        reasons = [eng.finished_requests[u].finish_reason for u in uids]
        assert reasons.count("shutdown") >= 1
        assert all(r in ("stop", "shutdown") for r in reasons)
        for u, r in zip(uids, reasons):
            assert (len(out[u]) > 0) == (r == "stop")

    def test_close_is_idempotent_and_blocks_submit(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        eng.submit(np.arange(2, 8), max_new_tokens=4)
        eng.close()
        eng.close()                               # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            eng.submit(np.arange(2, 8))
        assert all(r.finish_reason == "shutdown"
                   for r in eng.finished_requests.values())


# --------------------------------------------- speculative degradation


class TestSpecDegradation:
    def _spec_cfg(self, params):
        from repro.serving.spec import SpecConfig
        return SpecConfig(draft_params=params, k=2)

    def test_draft_kill_degrades_then_reenables(self, tiny_lm):
        """A draft-path crash degrades to plain decode (speculation is
        lossless, so streams are unchanged) and re-enables after the
        cool-down."""
        model, params = tiny_lm
        prompts = _prompts(30, 2)
        base, _ = _run(model, params, prompts, max_new=16,
                       spec_config=self._spec_cfg(params))
        plan = FaultPlan([FaultSpec("draft_kill", step=2)])
        got, eng = _run(model, params, prompts, max_new=16,
                        spec_config=self._spec_cfg(params), faults=plan,
                        policy=FaultPolicy(draft_cooldown_steps=3))
        assert got == base
        fs = eng.fault_stats()
        assert fs["draft_kills"] == 1
        assert fs["draft_reenables"] == 1
        assert not eng.degraded_components()      # healthy again at exit

    def test_spec_poison_quarantines_target_only(self, tiny_lm):
        model, params = tiny_lm
        prompts = _prompts(31, 3)
        base, _ = _run(model, params, prompts, max_new=12,
                       spec_config=self._spec_cfg(params))
        plan = FaultPlan([FaultSpec("poison_logits", step=2, uid=0)])
        got, eng = _run(model, params, prompts, max_new=12,
                        spec_config=self._spec_cfg(params), faults=plan)
        assert eng.finished_requests[0].finish_reason == "error"
        assert got[1] == base[1] and got[2] == base[2]
        assert eng.fault_stats()["quarantined"] == 1


# ----------------------------------------------- accounting + health


class TestAccountingAndHealth:
    def test_fault_stats_reconcile_with_plan(self, tiny_lm):
        """Every injected fault is accounted for: the engine's injected
        block equals the plan's fired log, nothing is outstanding, and
        the degradation counters match what each kind must trigger."""
        model, params = tiny_lm
        prompts = _prompts(32, 3)
        plan = FaultPlan([
            FaultSpec("poison_logits", step=2, uid=1),
            FaultSpec("alloc_fail", step=1),
            FaultSpec("straggler", step=4, delay_s=0.05),
        ])
        _, eng = _run(model, params, prompts, paged=True, faults=plan)
        fs = eng.fault_stats()
        assert fs["injected"] == plan.counts()
        assert fs["injected_total"] == 3 == len(plan.fired_log)
        assert plan.outstanding() == []
        assert fs["quarantined"] == 1           # the poison
        assert fs["straggler_slow"] >= 0        # soft flag, no timeout

    def test_snapshot_and_health_when_healthy(self, tiny_lm):
        model, params = tiny_lm
        _, eng = _run(model, params, _prompts(33, 2))
        assert eng.degraded_components() == {}
        snap = eng.engine_snapshot()
        for key in ("step", "ring_depth", "pipeline_depth", "slots",
                    "queued", "parked", "prefilling", "degraded",
                    "faults"):
            assert key in snap, key
        json.dumps(snap)

    def test_healthz_degraded_answers_503(self):
        from repro.obs import MetricsRegistry
        from repro.obs.metrics import MetricsServer

        state = {"bad": {}}
        srv = MetricsServer(MetricsRegistry(), port=0,
                            health=lambda: state["bad"])
        try:
            url = f"http://{srv.host}:{srv.port}/healthz"
            assert urllib.request.urlopen(url).status == 200
            state["bad"] = {"draft": {"off_until_step": 9}}
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(url)
            assert ei.value.code == 503
            body = json.loads(ei.value.read())
            assert body["status"] == "degraded"
            assert "draft" in body["components"]
        finally:
            srv.close()
