"""Self-speculative decoding tests: greedy token-equivalence with plain
decoding on both cache layouts (across block/chunk boundaries and under
continuous batching), the statistical guarantee that temperature>0
accept/resample preserves the target distribution, device-side EOS inside a
committed chunk, dynamic per-row windows, acceptance accounting, the
one-D2H-per-step contract, draft-pool lockstep reservation, the submit()
admission bugfixes, and the kvcache length-rollback API."""

from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockAllocator, PagedKVCache
from repro.serving.spec import SpecConfig
from repro.serving.spec.verify import verify_tail

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-spec", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def draft_params(tiny_lm):
    """A nearby-but-different draft: perturbed weights stand in for a
    higher-ratio NSVD twin (same pytree structure, different logits —
    exercises real rejections without a calibration pass)."""
    _, params = tiny_lm
    k = jax.random.key(99)
    return jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype)
        if x.ndim >= 2 else x,
        params,
    )


def _solo(model, params, prompt, max_new, max_len=64, **kw):
    eng = ServingEngine(model, params, max_batch=1, max_len=max_len, **kw)
    uid = eng.submit(prompt, max_new_tokens=max_new)
    return eng.run()[uid]


def _spec(draft, k=3, **kw):
    return SpecConfig(draft_params=draft, k=k, **kw)


# ------------------------------------------------------ greedy equivalence


class TestSpecGreedyEquivalence:
    @pytest.mark.parametrize("paged", [True, False])
    def test_identical_across_block_and_chunk_boundaries(self, tiny_lm,
                                                         draft_params, paged):
        """Speculative greedy decode must be token-identical to plain greedy
        decode on both layouts, for prompt lengths straddling block (16)
        and prefill-chunk boundaries."""
        model, params = tiny_lm
        rng = np.random.default_rng(1)
        for plen in (1, 15, 16, 17, 31, 33):
            p = rng.integers(2, 200, size=plen)
            plain = _solo(model, params, p, 8, paged=paged)
            spec = _solo(model, params, p, 8, paged=paged, prefill_chunk=16,
                         spec_config=_spec(draft_params))
            assert plain == spec, f"plen={plen} paged={paged}"

    @pytest.mark.parametrize("paged", [True, False])
    def test_batched_mid_flight_admission_identical(self, tiny_lm,
                                                    draft_params, paged):
        """Continuous batching with staggered finishes: every request's
        speculative greedy output matches its solo plain-decode run (paged
        chunked prefill AND dense bucketed admission feed the draft)."""
        model, params = tiny_lm
        rng = np.random.default_rng(2)
        prompts = [rng.integers(2, 200, size=n) for n in (6, 18, 7, 5)]
        lens = [9, 3, 6, 4]
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            paged=paged, spec_config=_spec(draft_params))
        uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, lens)]
        out = eng.run()
        for uid, p, m in zip(uids, prompts, lens):
            assert out[uid] == _solo(model, params, p, m, paged=paged), uid

    def test_perfect_draft_accepts_everything(self, tiny_lm):
        """Draft == target: every greedy proposal matches, so each step
        commits k+1 tokens and the acceptance rate is exactly 1."""
        model, params = tiny_lm
        rng = np.random.default_rng(3)
        p = rng.integers(2, 200, size=6)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            spec_config=_spec(params, k=3))
        uid = eng.submit(p, max_new_tokens=9)
        out = eng.run()
        assert out[uid] == _solo(model, params, p, 9)
        ss = eng.spec_stats()
        assert ss["acceptance_rate"] == 1.0
        assert ss["committed_per_row_step"] == 4.0  # k+1 per step


# ------------------------------------------------- distribution preservation


class TestSpecDistribution:
    def test_accept_resample_preserves_target_distribution(self):
        """Leviathan guarantee, pinned statistically: the marginal of the
        FIRST committed token equals the target distribution P0 exactly,
        whatever draft distribution the proposals came from."""
        V, K, N = 8, 2, 20000
        rng = np.random.default_rng(0)
        temp = 1.3
        t_logits = jnp.asarray(rng.standard_normal((1, K + 1, V)) * 1.5,
                               jnp.float32)
        q_logits = rng.standard_normal((K, V)) * 1.5
        q = np.exp(q_logits / temp)
        q /= q.sum(-1, keepdims=True)
        q_dev = jnp.asarray(q[None], jnp.float32)
        temps = jnp.asarray([temp])
        k_row = jnp.asarray([K])

        def one(key):
            kq, kv = jax.random.split(key)
            props = jax.vmap(jax.random.categorical)(
                jax.random.split(kq, K), jnp.asarray(q_logits) / temp
            )[None].astype(jnp.int32)
            kd = jax.random.key_data(kv)[None]
            _, _, _, out = verify_tail(kd, t_logits, q_dev, props, temps,
                                       k_row)
            return out[0, 0]

        toks = jax.vmap(one)(jax.random.split(jax.random.key(42), N))
        emp = np.bincount(np.asarray(toks), minlength=V) / N
        p0 = np.asarray(jax.nn.softmax(t_logits[0, 0] / temp))
        tv = 0.5 * np.abs(emp - p0).sum()
        assert tv < 0.03, f"total-variation distance {tv:.4f}"

    def test_greedy_rows_exact_prefix_match(self):
        """Greedy verification is deterministic: accept exactly the longest
        argmax-matching prefix, then commit the argmax correction."""
        V, K = 6, 3
        logits = np.full((1, K + 1, V), -5.0, np.float32)
        for i, t in enumerate((2, 4, 1, 3)):  # target argmax path
            logits[0, i, t] = 5.0
        proposals = jnp.asarray([[2, 4, 0]], jnp.int32)  # diverges at i=3
        kd = jax.random.key_data(jax.random.split(jax.random.key(0), 1))
        _, m, t_new, out = verify_tail(
            kd, jnp.asarray(logits), jnp.ones((1, K, V)) / V, proposals,
            jnp.asarray([0.0]), jnp.asarray([K]),
        )
        assert int(m[0]) == 2
        assert int(t_new[0]) == 1  # argmax after the accepted prefix
        assert np.asarray(out)[0, :3].tolist() == [2, 4, 1]

    def test_temperature_sampling_reproducible_and_in_vocab(self, tiny_lm,
                                                            draft_params):
        model, params = tiny_lm
        rng = np.random.default_rng(4)
        prompts = [rng.integers(2, 200, size=6) for _ in range(3)]

        def once():
            eng = ServingEngine(model, params, max_batch=2, max_len=64,
                                seed=9, spec_config=_spec(draft_params))
            uids = [eng.submit(p, max_new_tokens=6, temperature=0.7)
                    for p in prompts]
            out = eng.run()
            return [out[u] for u in uids]

        a, b = once(), once()
        assert a == b
        assert all(0 <= t < VOCAB for toks in a for t in toks)


# ----------------------------------------------------- EOS + dynamic windows


class TestSpecEosAndWindows:
    @pytest.mark.parametrize("paged", [True, False])
    def test_eos_inside_committed_chunk_truncates(self, tiny_lm,
                                                  draft_params, paged):
        """An EOS anywhere in a step's committed prefix must truncate the
        output at (and including) the EOS and stop the row — identical to
        plain decoding with the same eos id."""
        model, params = tiny_lm
        rng = np.random.default_rng(5)
        p = rng.integers(2, 200, size=7)
        full = _solo(model, params, p, 8, paged=paged)
        eos = full[2]
        spec = _solo(model, params, p, 8, paged=paged,
                     spec_config=_spec(draft_params), eos_id=eos)
        assert spec == full[:3]

    def test_dynamic_k_adapts_within_bounds_and_stays_exact(self, tiny_lm,
                                                            draft_params):
        model, params = tiny_lm
        rng = np.random.default_rng(6)
        prompts = [rng.integers(2, 200, size=n) for n in (6, 9)]
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            spec_config=_spec(draft_params, k=4,
                                              dynamic_k=True))
        uids = [eng.submit(p, max_new_tokens=10) for p in prompts]
        out = eng.run()
        assert (eng._k_row >= 1).all() and (eng._k_row <= 4).all()
        for uid, p in zip(uids, prompts):
            assert out[uid] == _solo(model, params, p, 10), uid

    def test_acceptance_accounting_per_request(self, tiny_lm, draft_params):
        model, params = tiny_lm
        rng = np.random.default_rng(7)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            spec_config=_spec(draft_params, k=3))
        eng.submit(rng.integers(2, 200, size=6), max_new_tokens=8)
        eng._admit()
        req = next(r for r in eng.slots if r is not None)
        eng.run()
        assert req.spec_proposed > 0
        assert 0 <= req.spec_accepted <= req.spec_proposed
        assert req.acceptance_rate == req.spec_accepted / req.spec_proposed
        ss = eng.spec_stats()
        assert ss["proposed"] == req.spec_proposed
        assert ss["accepted"] == req.spec_accepted
        # Every generated token beyond each request's admission token was
        # committed by a spec step.
        assert ss["committed"] == len(req.generated) - 1


# ------------------------------------------------------- engine contracts


class TestSpecEngineContracts:
    def test_exactly_one_device_to_host_transfer_per_step(self, tiny_lm,
                                                          draft_params):
        """Draft + verify are two jitted calls but ONE packed D2H."""
        model, params = tiny_lm
        rng = np.random.default_rng(8)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            spec_config=_spec(draft_params),
                            pipeline_depth=1)
        for _ in range(2):
            eng.submit(rng.integers(2, 200, size=6), max_new_tokens=12)
        eng._admit()

        real = jax.device_get
        calls = []

        def counting(x):
            calls.append(1)
            return real(x)

        with mock.patch.object(jax, "device_get", side_effect=counting):
            for _ in range(3):
                eng.step()
        assert len(calls) == 3

    def test_pipelined_spec_consumes_at_most_one_transfer(self, tiny_lm,
                                                          draft_params):
        """Depth-2 speculative steps also run the device one step ahead:
        first step() dispatches only, later ones consume one pack each."""
        model, params = tiny_lm
        rng = np.random.default_rng(8)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            spec_config=_spec(draft_params),
                            pipeline_depth=2)
        for _ in range(2):
            eng.submit(rng.integers(2, 200, size=6), max_new_tokens=12)
        eng._admit()

        real = jax.device_get
        calls = []

        def counting(x):
            calls.append(1)
            return real(x)

        with mock.patch.object(jax, "device_get", side_effect=counting):
            per_step = []
            for _ in range(3):
                before = len(calls)
                eng.step()
                per_step.append(len(calls) - before)
            eng.drain()
        assert per_step == [0, 1, 1]
        assert len(calls) == 3

    def test_draft_pool_reserved_and_freed_in_lockstep(self, tiny_lm,
                                                       draft_params):
        model, params = tiny_lm
        rng = np.random.default_rng(9)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            spec_config=_spec(draft_params))
        eng.submit(rng.integers(2, 200, size=9), max_new_tokens=4)
        eng._admit()
        assert eng.draft.kv.alloc.in_use() == eng.kv.alloc.in_use() > 0
        eng.run()
        assert eng.kv.alloc.in_use() == 0
        assert eng.draft.kv.alloc.in_use() == 0
        assert (eng.draft.kv.table_np == -1).all()

    def test_spec_rejects_non_attention_models(self):
        from repro.configs import get_config

        cfg = get_config("rwkv6-1.6b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        with pytest.raises(ValueError, match="speculative"):
            ServingEngine(model, params, max_batch=1, max_len=64,
                          spec_config=_spec(params))

    def test_spec_config_rejects_bad_k(self, tiny_lm):
        _, params = tiny_lm
        with pytest.raises(ValueError, match="k must be"):
            SpecConfig(draft_params=params, k=0)


# ------------------------------------------------- submit() admission fixes


class TestSubmitAdmissionFixes:
    def test_rejects_nonpositive_max_new_tokens(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        for bad in (0, -3):
            with pytest.raises(ValueError, match="max_new_tokens"):
                eng.submit(np.arange(2, 8), max_new_tokens=bad)

    def test_rejects_worst_case_exceeding_total_pool(self, tiny_lm):
        """A request whose worst-case reservation exceeds the WHOLE pool
        could never be admitted — it must fail at submit() instead of
        parking at the FIFO head and stalling admission forever."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            paged=True, num_blocks=1)
        with pytest.raises(ValueError, match="blocks"):
            eng.submit(np.arange(2, 22), max_new_tokens=13)  # needs 3 blocks

    def test_pool_sized_request_still_admits(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            paged=True, num_blocks=3)
        uid = eng.submit(np.arange(2, 22), max_new_tokens=13)
        out = eng.run()
        assert len(out[uid]) == 13


# --------------------------------------------------- cache rollback API


class TestCacheRollbackAPI:
    def test_allocator_release_suffix(self):
        a = BlockAllocator(8)
        a.alloc("r", 5)
        assert a.release_suffix("r", 2) == [2, 3, 4]
        assert a.owned_by("r") == [0, 1]
        assert a.free_blocks() == 6
        assert a.release_suffix("r", 2) == []  # idempotent at the bound
        assert a.release_suffix("r", 0) == [0, 1]
        assert a.owned_by("r") == [] and a.in_use() == 0

    def test_paged_rollback_trims_table_and_blocks(self, tiny_lm):
        model, _ = tiny_lm
        kv = PagedKVCache(model, max_batch=2, max_len=64, block_size=16,
                          num_blocks=4)
        assert kv.reserve(0, 50)  # 4 blocks
        freed = kv.rollback(0, 17)  # 2 blocks cover 17 tokens
        assert len(freed) == 2
        assert (kv.table_np[0, :2] >= 0).all()
        assert (kv.table_np[0, 2:] == -1).all()
        assert kv.alloc.free_blocks() == 2
        # The freed suffix is immediately reusable by another slot.
        assert kv.reserve(1, 20)
        # Rolling back to zero tokens evicts the row entirely.
        assert len(kv.rollback(0, 0)) == 2
        assert (kv.table_np[0] == -1).all()
        assert kv.alloc.owned_by(0) == []
