"""Observability-layer tests: telemetry must be a pure observer.

The load-bearing invariants: (1) greedy token streams are BIT-IDENTICAL
with telemetry on vs off, at every pipeline depth, on both cache layouts
— instrumentation may never perturb the serving path; (2) request-level
metrics (submitted/finished/tokens, per-request event multiset) are
invariant across pipeline depths and mesh shapes — depth changes WHEN
host bookkeeping runs, never WHAT it observes; (3) the disabled path is a
pinned no-op (shared NULL_TELEMETRY singleton, one reused nullcontext
span); (4) the exports are well-formed (Prometheus 0.0.4 text, loadable
Chrome trace, JSONL) and the ring buffer is bounded with an honest
dropped count."""

import json
import urllib.error
import urllib.request
from collections import Counter as MultiSet

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.obs import (
    NULL_TELEMETRY,
    EventTracer,
    MetricsRegistry,
    MetricsServer,
    Telemetry,
    disabled,
)
from repro.serving.engine import ServingEngine
from repro.serving.spec import SpecConfig

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-obs", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def draft_params(tiny_lm):
    _, params = tiny_lm
    k = jax.random.key(99)
    return jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype)
        if x.ndim >= 2 else x,
        params,
    )


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(1)
    return [rng.integers(2, 200, size=n) for n in (6, 18, 7, 5)]


LENS = [9, 3, 6, 4]


def _serve(model, params, depth, prompts, lens, telemetry=None, **kw):
    eng = ServingEngine(model, params, max_batch=2, max_len=64, seed=0,
                        pipeline_depth=depth, telemetry=telemetry, **kw)
    uids = [eng.submit(p, max_new_tokens=m)
            for p, m in zip(prompts, lens)]
    out = eng.run()
    return [out[u] for u in uids], eng


def _counter_value(tel, name):
    fam = tel.metrics.snapshot()[name]
    return sum(s["value"] for s in fam["series"])


def _request_event_multiset(tel):
    """Per-request lifecycle events as a {(name, tid): n} multiset —
    depth- and mesh-invariant, unlike step events whose timing varies."""
    return MultiSet(
        (e.name, e.tid) for e in tel.tracer.events() if e.cat == "request"
        and e.name != "preempt_ready"
    )


# --------------------------------------------------- bit-identity pins


class TestBitIdentity:
    @pytest.mark.parametrize("paged", [True, False])
    def test_greedy_streams_identical_with_telemetry(self, tiny_lm, prompts,
                                                     paged):
        model, params = tiny_lm
        base, _ = _serve(model, params, 1, prompts, LENS, paged=paged)
        for depth in (1, 2, 4):
            got, eng = _serve(model, params, depth, prompts, LENS,
                              telemetry=Telemetry(), paged=paged)
            assert got == base, f"depth={depth} paged={paged}"
            assert eng.obs.enabled

    def test_spec_streams_identical_with_telemetry(self, tiny_lm,
                                                   draft_params, prompts):
        model, params = tiny_lm
        sc = lambda: SpecConfig(draft_params=draft_params, k=3,  # noqa: E731
                                draft_ratio=0.6)
        base, _ = _serve(model, params, 1, prompts, LENS, paged=True,
                         spec_config=sc())
        got, eng = _serve(model, params, 2, prompts, LENS, paged=True,
                          spec_config=sc(), telemetry=Telemetry())
        assert got == base
        assert eng.obs.spec_meta == {"k": 3, "draft_ratio": 0.6}


# ------------------------------------------ depth / mesh invariance


class TestInvariance:
    @pytest.mark.parametrize("paged", [True, False])
    def test_request_metrics_invariant_across_depths(self, tiny_lm, prompts,
                                                     paged):
        model, params = tiny_lm
        snaps = {}
        for depth in (1, 2, 4):
            _, eng = _serve(model, params, depth, prompts, LENS,
                            telemetry=Telemetry(), paged=paged)
            tel = eng.obs
            snaps[depth] = {
                "submitted": _counter_value(
                    tel, "serving_requests_submitted_total"),
                "finished": _counter_value(
                    tel, "serving_requests_finished_total"),
                "tokens": _counter_value(
                    tel, "serving_tokens_emitted_total"),
                "events": _request_event_multiset(tel),
            }
        assert snaps[1] == snaps[2] == snaps[4]
        assert snaps[1]["submitted"] == len(prompts)
        assert snaps[1]["finished"] == len(prompts)
        assert snaps[1]["tokens"] == sum(LENS)

    @pytest.mark.skipif(jax.device_count() < 4,
                        reason="needs 4 (emulated) devices")
    def test_request_metrics_invariant_across_mesh(self, tiny_lm, prompts):
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel.sharding import make_parallelism

        model, params = tiny_lm
        results = {}
        for dp, tp in ((1, 1), (2, 2)):
            par = (make_parallelism(make_serving_mesh(dp, tp))
                   if dp * tp > 1 else None)
            toks, eng = _serve(model, params, 2, prompts, LENS,
                               telemetry=Telemetry(), paged=True,
                               parallelism=par)
            tel = eng.obs
            results[(dp, tp)] = (toks, _request_event_multiset(tel),
                                 _counter_value(
                                     tel, "serving_tokens_emitted_total"))
        assert results[(1, 1)] == results[(2, 2)]


# --------------------------------------------------- disabled no-op pin


class TestDisabledPath:
    def test_engine_default_is_null_singleton(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        assert eng.obs is NULL_TELEMETRY
        assert not eng.obs.enabled
        assert disabled() is NULL_TELEMETRY

    def test_null_span_is_one_reused_nullcontext(self):
        a = NULL_TELEMETRY.span("x")
        b = NULL_TELEMETRY.span("y")
        assert a is b  # no per-call allocation on the disabled hot path
        with a:
            pass

    def test_null_hooks_are_stateless_noops(self):
        NULL_TELEMETRY.on_submit(0, 1, 2)
        NULL_TELEMETRY.on_step_dispatch("decode", 1, 2, 0.1)
        NULL_TELEMETRY.on_spec_row(4, 2)
        assert NULL_TELEMETRY.snapshot() == {}
        assert not hasattr(NULL_TELEMETRY, "__dict__")  # __slots__ pin


# -------------------------------------------------- event stream shape


class TestEventStream:
    def test_lifecycle_ordering_per_request(self, tiny_lm, prompts):
        model, params = tiny_lm
        _, eng = _serve(model, params, 2, prompts, LENS,
                        telemetry=Telemetry(), paged=True)
        by_uid = {}
        for e in eng.obs.tracer.events():
            if e.cat == "request":
                by_uid.setdefault(e.tid, []).append(e.name)
        assert set(by_uid) == set(range(len(prompts)))
        order = {"submit": 0, "admit": 1, "first_chunk": 2,
                 "first_token": 3, "commit": 4, "finish": 5}
        for uid, names in by_uid.items():
            assert names[0] == "submit" and names[-1] == "finish"
            # submit < admit < first_chunk < first_token <= commits < finish
            ranks = [order[n] for n in names if n != "commit"]
            assert ranks == sorted(ranks), f"uid={uid}: {names}"

    def test_timestamps_monotone_within_request(self, tiny_lm, prompts):
        model, params = tiny_lm
        _, eng = _serve(model, params, 1, prompts, LENS,
                        telemetry=Telemetry())
        by_uid = {}
        for e in eng.obs.tracer.events():
            if e.cat == "request":
                by_uid.setdefault(e.tid, []).append(e.ts_us)
        for uid, ts in by_uid.items():
            assert ts == sorted(ts), f"uid={uid}"

    def test_ring_buffer_bound_and_dropped_count(self):
        tr = EventTracer(capacity=8)
        for i in range(20):
            tr.instant(f"e{i}", "step", 0, 0)
        assert len(tr) == 8
        assert tr.dropped == 12
        assert [e.name for e in tr.events()] == [f"e{i}" for i in
                                                 range(12, 20)]
        ct = tr.chrome_trace()
        assert ct["otherData"]["dropped_events"] == 12

    def test_chrome_trace_loadable(self, tiny_lm, prompts, tmp_path):
        model, params = tiny_lm
        _, eng = _serve(model, params, 2, prompts, LENS,
                        telemetry=Telemetry(), paged=True)
        p = tmp_path / "trace.json"
        eng.obs.tracer.export_chrome(str(p))
        doc = json.loads(p.read_text())
        evs = doc["traceEvents"]
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   for e in evs)
        for e in evs:
            if e["ph"] == "X":
                assert e["dur"] >= 0 and "ts" in e
            if e["ph"] == "i":
                assert e["s"] == "t"
        names = {e["name"] for e in evs}
        assert {"submit", "finish", "dispatch:decode",
                "sync:decode"} <= names

    def test_jsonl_export_round_trips(self, tiny_lm, prompts, tmp_path):
        model, params = tiny_lm
        _, eng = _serve(model, params, 1, prompts, LENS,
                        telemetry=Telemetry())
        p = tmp_path / "trace.jsonl"
        eng.obs.tracer.export_jsonl(str(p))
        lines = [json.loads(ln) for ln in p.read_text().splitlines()]
        assert len(lines) == len(eng.obs.tracer)
        assert all("name" in ln and "ts" in ln for ln in lines)

    def test_chrome_trace_on_empty_ring(self, tmp_path):
        """A tracer that never recorded must still export a loadable
        trace: just the process-name metadata, honest zero counts."""
        tr = EventTracer(capacity=4)
        doc = tr.chrome_trace()
        assert all(e["ph"] == "M" for e in doc["traceEvents"])
        assert doc["otherData"] == {"dropped_events": 0, "total_events": 0}
        p = tmp_path / "empty.json"
        tr.export_chrome(str(p))
        assert json.loads(p.read_text())["traceEvents"]
        p2 = tmp_path / "empty.jsonl"
        tr.export_jsonl(str(p2))
        assert p2.read_text() == ""

    def test_instant_timestamps_monotone(self):
        """Auto-stamped instants never go backwards, and an explicit
        ts_us override lands verbatim (the engine backdates nothing)."""
        tr = EventTracer()
        for i in range(50):
            tr.instant(f"e{i}", "step")
        ts = [e.ts_us for e in tr.events()]
        assert ts == sorted(ts)
        assert all(t >= 0.0 for t in ts)
        tr.instant("pinned", "step", ts_us=123.5)
        assert tr.events()[-1].ts_us == 123.5

    def test_chrome_events_carry_required_keys(self):
        """Perfetto's legacy loader needs name/ph/ts/pid/tid on every
        event, dur on X (complete) and a scope on i (instant)."""
        tr = EventTracer()
        tr.instant("inst", "cat", pid=1, tid=7, args={"k": 1})
        tr.complete("span", "cat", dur_s=0.002, pid=0, tid=3)
        evs = [e.to_chrome() for e in tr.events()]
        for e in evs:
            assert {"name", "cat", "ph", "ts", "pid", "tid"} <= e.keys()
        inst = next(e for e in evs if e["ph"] == "i")
        assert inst["s"] == "t" and "dur" not in inst
        assert inst["args"] == {"k": 1}
        span = next(e for e in evs if e["ph"] == "X")
        assert span["dur"] == pytest.approx(2000.0)
        # complete() backdates the start by dur: end = ts + dur is "now"
        assert span["ts"] + span["dur"] >= inst["ts"]
        assert "s" not in span


# ----------------------------------------------------- metrics registry


class TestMetrics:
    def test_histogram_percentiles_and_buckets(self):
        reg = MetricsRegistry()
        h = reg.histogram("h_test", "t", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 3.0, 8.0):
            h.observe(v)
        assert h.count == 4 and h.max == 8.0
        assert h.percentile(50) == pytest.approx(1.5, abs=1.6)
        snap = h.snapshot()
        assert snap["buckets"]["4.0"] == 3  # cumulative <= 4.0
        assert snap["count"] == 4  # overflow sample still counted
        h.percentile(101)  # out-of-range q clamps, never raises

    def test_empty_histogram_is_zero_not_nan(self):
        reg = MetricsRegistry()
        h = reg.histogram("h_empty", "t", buckets=(1.0,))
        assert h.percentile(50) == 0.0
        assert h.mean() == 0.0

    def test_reregistration_must_match(self):
        reg = MetricsRegistry()
        reg.counter("c1", "x")
        assert reg.counter("c1", "x") is reg.counter("c1", "x")
        with pytest.raises(ValueError):
            reg.gauge("c1", "x")

    def test_prometheus_text_exposition(self, tiny_lm, prompts):
        model, params = tiny_lm
        _, eng = _serve(model, params, 2, prompts, LENS,
                        telemetry=Telemetry(), paged=True)
        txt = eng.obs.metrics.prometheus_text()
        assert "# TYPE serving_requests_submitted_total counter" in txt
        assert "# TYPE serving_ttft_seconds histogram" in txt
        assert 'le="+Inf"' in txt
        assert 'serving_pool_blocks_in_use{shard="0"}' in txt
        for line in txt.splitlines():
            if line and not line.startswith("#"):
                assert len(line.rsplit(" ", 1)) == 2

    def test_metrics_server_http_smoke(self):
        reg = MetricsRegistry()
        reg.counter("smoke_total", "x").inc(3)
        srv = MetricsServer(reg, port=0)
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/metrics") as r:
                body = r.read().decode()
                assert "smoke_total 3" in body
                assert r.headers["Content-Type"].startswith("text/plain")
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/metrics.json") as r:
                doc = json.loads(r.read())
                assert doc["smoke_total"]["series"][0]["value"] == 3
        finally:
            srv.close()

    def test_metrics_server_healthz_and_shutdown(self):
        """/healthz answers while the server lives; close() releases the
        port (a daemon thread must not linger holding the socket)."""
        reg = MetricsRegistry()
        srv = MetricsServer(reg, port=0)
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/healthz") as r:
                assert r.status == 200
                assert r.read() == b"ok\n"
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/nope")
        finally:
            srv.close()
        with pytest.raises(urllib.error.URLError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/healthz", timeout=2)


# ---------------------------------------------- engine-side accounting


class TestEngineAccounting:
    def test_empty_stats_fully_keyed(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        s = eng.stats()
        assert s["steps"] == 0
        for key in ("step_mean_s", "step_p50_s", "step_p90_s",
                    "step_p99_s", "device_wait_mean_s",
                    "device_wait_p50_s", "host_mean_s", "host_p50_s"):
            assert s[key] == 0.0
        assert s["pipeline_depth"] >= 1 and s["live_rows"] == 0
        assert eng.telemetry_snapshot() == {}

    def test_empty_spec_stats_division_safe(self, tiny_lm, draft_params):
        model, params = tiny_lm
        eng = ServingEngine(
            model, params, max_batch=2, max_len=64, paged=True,
            spec_config=SpecConfig(draft_params=draft_params, k=3))
        ss = eng.spec_stats()
        assert ss["proposed"] == 0 and ss["acceptance_rate"] == 0.0
        assert ss["committed_per_row_step"] == 0.0
        assert np.isfinite(list(
            v for v in ss.values() if isinstance(v, float))).all()

    def test_allocator_lifetime_counters(self, tiny_lm, prompts):
        model, params = tiny_lm
        _, eng = _serve(model, params, 2, prompts, LENS,
                        telemetry=Telemetry(), paged=True)
        c = eng.kv.alloc.counters
        assert c["alloc_calls"] > 0 and c["alloc_blocks"] > 0
        assert c["freed_blocks"] == c["alloc_blocks"]  # all requests done
        snap = eng.telemetry_snapshot()
        assert snap["engine"]["allocator"] == c

    def test_spec_outcome_accounting_matches_engine(self, tiny_lm,
                                                    draft_params, prompts):
        model, params = tiny_lm
        _, eng = _serve(
            model, params, 2, prompts, LENS, paged=True,
            telemetry=Telemetry(),
            spec_config=SpecConfig(draft_params=draft_params, k=3,
                                   draft_ratio=0.6))
        tel = eng.obs
        block = tel.bench_block()
        spec = block["spec"]
        assert spec is not None
        assert spec["k"] == 3 and spec["draft_ratio"] == 0.6
        assert spec["row_steps"] == eng.spec_step_rows
        accepted = sum(o["accepted"] * o["rows"] for o in spec["outcomes"])
        proposed = sum(o["k"] * o["rows"] for o in spec["outcomes"])
        assert accepted == eng.spec_accepted
        assert proposed == eng.spec_proposed
        assert spec["acceptance_rate"] == pytest.approx(
            eng.spec_stats()["acceptance_rate"])

    def test_bench_block_shape(self, tiny_lm, prompts):
        model, params = tiny_lm
        _, eng = _serve(model, params, 2, prompts, LENS,
                        telemetry=Telemetry(), paged=True)
        bb = eng.obs.bench_block()
        assert bb["ttft_s"]["count"] == len(prompts)
        assert bb["tokens"] == sum(LENS)
        assert bb["steps"] > 0
        assert 0 < bb["occupancy"]["rows_peak"] <= 2
        assert 0.0 < bb["occupancy"]["pool_frac_peak"] <= 1.0
        assert bb["spec"] is None
        json.dumps(bb)  # must be JSON-serializable as-is

    def test_preempt_ready_fires_under_pool_pressure(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(3)
        tel = Telemetry()
        # A pool sized for ~one long row forces FIFO backpressure while a
        # row is live -> the engine flags the fattest live row.
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            paged=True, num_blocks=4, block_size=16,
                            telemetry=tel)
        for _ in range(3):
            eng.submit(rng.integers(2, 200, size=12), max_new_tokens=30)
        eng.run()
        assert tel.preempt_ready.value >= 1
        assert any(e.name == "preempt_ready"
                   for e in tel.tracer.events())


# ------------------------------------------------ instrumented roots


class TestInstrumentedRoots:
    def test_registry_roots_carry_obs_marker(self, tiny_lm):
        from repro.launch.steps import RootContext, serving_root_registry

        model, _ = tiny_lm
        ctx = RootContext(model=model, max_batch=2, max_len=64)
        seen = []
        for layout in ("dense", "paged"):
            for spec in serving_root_registry(layout, spec=True):
                fn = spec.build(ctx)
                assert hasattr(fn, "__obs_name__"), (layout, spec.name)
                seen.append(fn.__obs_name__)
        assert "paged_decode" in seen and "decode" in seen

    def test_profile_capture_writes_trace(self, tiny_lm, prompts, tmp_path):
        model, params = tiny_lm
        prof_dir = tmp_path / "prof"
        tel = Telemetry(profile_dir=str(prof_dir), profile_steps=2)
        _serve(model, params, 1, prompts, LENS, telemetry=tel)
        if tel.profile is not None:
            tel.profile.stop()
        files = list(prof_dir.rglob("*")) if prof_dir.exists() else []
        assert any(f.is_file() for f in files), "no profiler artifacts"
