"""Mesh-sharded serving regression tests.

Pins the two SPMD contracts the engine guarantees:

  * a (1, 1) serving mesh is BIT-FOR-BIT the meshless single-device path
    (runs everywhere, including the plain 1-device tier), and
  * a (2, 2) DP x TP mesh — weights tensor-parallel, slots/pools
    data-parallel with per-shard block ranges — serves token-identically
    (greedy AND temperature AND speculative) to the single-device engine
    on both cache layouts, with the donation and one-D2H-per-step
    contracts intact.

The (2, 2) tests need 4 devices: run with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the dedicated CI
job does); on a 1-device host they skip.  Shard-aware BlockAllocator
bookkeeping (per-shard free lists, peaks, shard-local defrag) is pure host
logic and runs everywhere."""

from unittest import mock

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.parallel.sharding import make_parallelism
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockAllocator
from repro.serving.spec import SpecConfig

VOCAB = 256

need4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-sharded", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def draft_params(tiny_lm):
    """Perturbed weights stand in for a higher-ratio NSVD twin (same pytree
    structure, different logits — exercises real rejections/rollbacks)."""
    _, params = tiny_lm
    k = jax.random.key(99)
    return jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype)
        if x.ndim >= 2 else x,
        params,
    )


@pytest.fixture(scope="module")
def par22():
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    return make_parallelism(make_serving_mesh(2, 2))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(1)
    return [rng.integers(2, 200, size=n) for n in (6, 9, 5, 7)]


def _serve(model, params, prompts, par=None, max_new=6, temperature=0.0,
           **kw):
    eng = ServingEngine(model, params, max_batch=4, max_len=64,
                        parallelism=par, **kw)
    uids = [eng.submit(p, max_new_tokens=max_new, temperature=temperature)
            for p in prompts]
    out = eng.run()
    return [out[u] for u in uids], eng


# ------------------------------------------------------------ mesh factory


class TestMakeServingMesh:
    def test_oversubscribed_mesh_warns_and_falls_back_to_11(self):
        with pytest.warns(UserWarning, match="falling back"):
            mesh = make_serving_mesh(jax.device_count() + 1, 1)
        assert dict(mesh.shape) == {"data": 1, "model": 1}

    def test_rejects_nonpositive_axes(self):
        with pytest.raises(ValueError, match="positive"):
            make_serving_mesh(0, 2)

    @need4
    def test_22_mesh_on_four_devices(self):
        mesh = make_serving_mesh(2, 2)
        assert dict(mesh.shape) == {"data": 2, "model": 2}


# --------------------------------------------------- shard-aware allocator


class TestShardedBlockAllocator:
    def test_single_shard_matches_legacy_behavior(self):
        a = BlockAllocator(8)
        assert a.alloc("r", 3) == [0, 1, 2]
        assert a.free("r") == [0, 1, 2]
        assert a.peak_in_use == 3 and a.peak_by_shard == [3]

    def test_per_shard_ranges_and_backpressure(self):
        a = BlockAllocator(8, num_shards=2)
        assert a.alloc("r0", 3, shard=0) == [0, 1, 2]
        assert a.alloc("r1", 3, shard=1) == [4, 5, 6]
        # Shard 0 has one block left: a 2-block ask backpressures even
        # though the OTHER shard could serve it.
        assert a.alloc("r2", 2, shard=0) is None
        assert a.alloc("r2", 1, shard=1) == [7]
        assert a.in_use() == 7
        assert a.in_use(0) == 3 and a.in_use(1) == 4

    def test_free_returns_blocks_to_home_shards(self):
        a = BlockAllocator(8, num_shards=2)
        a.alloc("r", 2, shard=0)
        a.alloc("r", 2, shard=1)  # one owner spanning shards
        a.free("r")
        assert a.free_blocks(0) == 4 and a.free_blocks(1) == 4
        assert a.alloc("x", 4, shard=1) == [4, 5, 6, 7]

    def test_peak_accounting_per_shard_and_aggregate(self):
        a = BlockAllocator(8, num_shards=2)
        a.alloc("r0", 3, shard=0)
        a.free("r0")
        a.alloc("r1", 2, shard=1)
        # Aggregate peak (3) is NOT the sum of per-shard peaks (3 + 2):
        # the shards peaked at different times.
        assert a.peak_in_use == 3
        assert a.peak_by_shard == [3, 2]

    def test_defrag_is_shard_local(self):
        a = BlockAllocator(8, num_shards=2)
        a.alloc("r0", 2, shard=0)
        a.alloc("r1", 2, shard=1)   # blocks 4, 5
        a.alloc("r2", 1, shard=1)   # block 6
        a.free("r1")
        moves = a.defrag()
        # r2's block compacts to the bottom OF ITS SHARD (4), never to
        # shard 0's free ids 2..3.
        assert moves == {6: 4}
        assert a.owned_by("r2") == [4]
        assert a.free_blocks(0) == 2 and a.free_blocks(1) == 3

    def test_rejects_indivisible_sharding(self):
        with pytest.raises(ValueError, match="divisible"):
            BlockAllocator(7, num_shards=2)


# --------------------------------------------- (1,1) mesh == meshless path


class TestMesh11Equivalence:
    """The invariant every other mesh test builds on: a (1, 1) mesh changes
    nothing — same tokens, same layouts, same stats."""

    def test_bitwise_equal_tokens_both_layouts(self, tiny_lm, prompts):
        model, params = tiny_lm
        par11 = make_parallelism(make_serving_mesh(1, 1))
        for paged in (True, False):
            base, be = _serve(model, params, prompts, paged=paged)
            mesh, me = _serve(model, params, prompts, par=par11, paged=paged)
            assert mesh == base
            assert me.dp_shards == 1
            assert me.cache_stats()["mesh"] == {"dp": 1, "tp": 1,
                                                "devices": 1}
            assert (me.cache_stats()["per_device_cache_hbm_bytes"]
                    == be.cache_stats()["cache_hbm_bytes"])


# ------------------------------------------------- (2,2) DP x TP SPMD path


@need4
class TestSharded22Equivalence:
    def test_greedy_identical_both_layouts(self, tiny_lm, prompts, par22):
        model, params = tiny_lm
        for paged in (True, False):
            base, _ = _serve(model, params, prompts, paged=paged)
            shard, eng = _serve(model, params, prompts, par=par22,
                                paged=paged)
            assert shard == base, f"paged={paged}"
            assert eng.dp_shards == 2
            assert eng.cache_stats()["mesh"] == {"dp": 2, "tp": 2,
                                                 "devices": 4}

    def test_temperature_sampling_identical_both_layouts(self, tiny_lm,
                                                         prompts, par22):
        """Per-slot PRNG keys are slot state, so sharding must not change
        the sampled stream."""
        model, params = tiny_lm
        for paged in (True, False):
            base, _ = _serve(model, params, prompts, paged=paged,
                             temperature=0.7)
            shard, _ = _serve(model, params, prompts, par=par22,
                              paged=paged, temperature=0.7)
            assert shard == base, f"paged={paged}"

    def test_int8_kv_quant_identical(self, tiny_lm, prompts, par22):
        model, params = tiny_lm
        base, _ = _serve(model, params, prompts, paged=True, kv_quant=True)
        shard, _ = _serve(model, params, prompts, par=par22, paged=True,
                          kv_quant=True)
        assert shard == base

    def test_spec_decoding_identical_both_layouts(self, tiny_lm, prompts,
                                                  par22, draft_params):
        """Speculative draft+verify (including per-step cache-length
        rollback of rejected proposals) under the mesh: same committed
        tokens AND same acceptance accounting as the unsharded engine."""
        model, params = tiny_lm
        spec = SpecConfig(draft_params=draft_params, k=3)
        for paged in (True, False):
            plain, _ = _serve(model, params, prompts, paged=paged)
            base, b_eng = _serve(model, params, prompts, paged=paged,
                                 spec_config=spec)
            shard, s_eng = _serve(model, params, prompts, par=par22,
                                  paged=paged, spec_config=spec)
            assert shard == plain == base, f"paged={paged}"
            bs, ss = b_eng.spec_stats(), s_eng.spec_stats()
            assert (ss["proposed"], ss["accepted"], ss["committed"]) == \
                (bs["proposed"], bs["accepted"], bs["committed"])

    def test_mid_flight_defrag_with_spec_rollback(self, tiny_lm, prompts,
                                                  par22, draft_params):
        """Shard-local defrag (block-diagonal donated permutation of BOTH
        sharded pools) between speculative steps must not change a single
        committed token."""
        model, params = tiny_lm
        spec = SpecConfig(draft_params=draft_params, k=3)
        base, _ = _serve(model, params, prompts, spec_config=spec)

        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            parallelism=par22, spec_config=spec)
        uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
        finished = {}
        for step in range(200):
            for r in eng._admit():
                finished[r.uid] = r.generated
            if not eng.active.any():
                if not eng.queue and not eng._prefilling:
                    break
                continue
            for r in eng.step():
                finished[r.uid] = r.generated
            eng.defrag()  # compact target + draft pools mid-flight
        assert [finished[u] for u in uids] == base

    def test_sharded_pools_donated_in_place(self, tiny_lm, prompts, par22):
        """Donation must survive explicit NamedShardings: every per-shard
        buffer of the block pools is reused across decode steps."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            parallelism=par22)
        eng.submit(prompts[0], max_new_tokens=8)
        eng._admit()
        leaf = jax.tree.leaves(eng.kv.pools)[0]
        assert len(leaf.sharding.device_set) == 4
        ptrs = sorted(s.data.unsafe_buffer_pointer()
                      for s in leaf.addressable_shards)
        eng.step()
        after = sorted(s.data.unsafe_buffer_pointer()
                       for s in jax.tree.leaves(eng.kv.pools)[0]
                       .addressable_shards)
        assert after == ptrs

    def test_sharded_dense_slab_donated_in_place(self, tiny_lm, prompts,
                                                 par22):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            paged=False, parallelism=par22)
        eng.submit(prompts[0], max_new_tokens=8)
        eng._admit()
        leaf = jax.tree.leaves(eng.cache)[0]
        ptrs = sorted(s.data.unsafe_buffer_pointer()
                      for s in leaf.addressable_shards)
        eng.step()
        after = sorted(s.data.unsafe_buffer_pointer()
                       for s in jax.tree.leaves(eng.cache)[0]
                       .addressable_shards)
        assert after == ptrs

    def test_exactly_one_device_to_host_transfer_per_step(self, tiny_lm,
                                                          prompts, par22):
        """Sampled tokens leave through ONE sharded D2H transfer, not one
        per shard."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            parallelism=par22, pipeline_depth=1)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        eng._admit()
        real = jax.device_get
        calls = []

        def counting(x):
            calls.append(1)
            return real(x)

        with mock.patch.object(jax, "device_get", side_effect=counting):
            for _ in range(4):
                eng.step()
        assert len(calls) == 4

    def test_pipelined_depth2_identical_under_mesh(self, tiny_lm, prompts,
                                                   par22, draft_params):
        """The depth-2 step pipeline composes with SPMD: greedy,
        temperature (slot-reusing workload) and speculative streams under
        a (2, 2) mesh match the depth-1 sharded engine on both layouts,
        consuming at most one sharded D2H per step."""
        model, params = tiny_lm
        extra = [np.asarray(p[::-1]) for p in prompts]  # force slot reuse
        work = list(prompts) + extra
        lens = [6, 4, 7, 3, 5, 6, 4, 5]

        def serve(depth, temperature=0.0, spec=None, paged=True):
            eng = ServingEngine(model, params, max_batch=4, max_len=64,
                                parallelism=par22, paged=paged,
                                spec_config=spec, pipeline_depth=depth)
            uids = [eng.submit(p, max_new_tokens=m, temperature=temperature)
                    for p, m in zip(work, lens)]
            out = eng.run()
            assert eng.decode_transfers == len(eng.step_times)
            return [out[u] for u in uids]

        for paged in (True, False):
            assert serve(2, paged=paged) == serve(1, paged=paged)
            assert (serve(2, temperature=0.7, paged=paged)
                    == serve(1, temperature=0.7, paged=paged))
        spec = SpecConfig(draft_params=draft_params, k=3)
        assert serve(2, spec=spec) == serve(1, spec=spec)

    def test_weights_are_tensor_sharded(self, tiny_lm, par22):
        """TP actually engages: attention projections shard over 'model'."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            parallelism=par22)
        wq = eng.params["g0"]["sub0"]["attn"]["wq"]["kernel"]
        assert "model" in str(wq.sharding.spec)
        assert len(wq.sharding.device_set) == 4

    def test_per_shard_admission_and_peaks(self, tiny_lm, par22):
        """Slots map to DP shards; reservations come from the slot's shard
        range and per-shard peaks stay within the sub-pool."""
        model, params = tiny_lm
        rng = np.random.default_rng(5)
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            paged=True, num_blocks=8, parallelism=par22)
        assert eng.kv.dp_shards == 2 and eng.kv.blocks_per_shard == 4
        uids = [eng.submit(rng.integers(2, 200, size=9), max_new_tokens=4)
                for _ in range(4)]
        out = eng.run()
        assert len(out) == len(uids)
        st = eng.kv.stats()
        assert len(st["blocks_peak_by_shard"]) == 2
        assert all(0 < p <= 4 for p in st["blocks_peak_by_shard"])
        assert st["per_device_cache_hbm_bytes"] * 2 == st["cache_hbm_bytes"]

    def test_pad_sensitive_exact_length_prefill_under_mesh(self, par22):
        """Recurrent caches fall back to exact-length rows=1 admission,
        which cannot split over DP: those inputs stay replicated while
        slot state keeps its sharding — and tokens still match the
        meshless engine."""
        from repro.configs import get_config

        cfg = get_config("rwkv6-1.6b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(11)
        ps = [rng.integers(2, 200, size=n) for n in (5, 6)]

        def serve(par):
            eng = ServingEngine(model, params, max_batch=2, max_len=64,
                                parallelism=par)
            assert not eng._bucketed
            uids = [eng.submit(p, max_new_tokens=3) for p in ps]
            out = eng.run()
            return [out[u] for u in uids]

        assert serve(par22) == serve(None)

    def test_indivisible_max_batch_keeps_tp_drops_dp(self, tiny_lm, prompts,
                                                     par22):
        """max_batch=3 doesn't divide dp=2: slots/pools fall back to
        replicated (single-shard bookkeeping) while weights stay TP — and
        tokens still match the meshless engine."""
        model, params = tiny_lm

        def serve3(par):
            eng = ServingEngine(model, params, max_batch=3, max_len=64,
                                parallelism=par)
            uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
            out = eng.run()
            return [out[u] for u in uids], eng

        base, _ = serve3(None)
        shard, eng = serve3(par22)
        assert shard == base
        assert eng.dp_shards == 1 and eng.kv.dp_shards == 1
        wq = eng.params["g0"]["sub0"]["attn"]["wq"]["kernel"]
        assert "model" in str(wq.sharding.spec)

    def test_submit_rejects_worst_case_exceeding_shard_subpool(self, tiny_lm,
                                                               par22):
        """With the pool split over DP shards, the admissibility bound is
        the per-shard sub-pool, not the global block count."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            paged=True, num_blocks=4, parallelism=par22)
        with pytest.raises(ValueError, match="shard"):
            eng.submit(np.arange(2, 22), max_new_tokens=13)  # needs 3 > 2


# ------------------------------------------------ bench schema migration


class TestBenchSchemaMigration:
    def test_schema2_entries_gain_mesh_and_pipeline_stamps(self, tmp_path):
        st = pytest.importorskip("benchmarks.serving_throughput")
        import json

        path = tmp_path / "BENCH_serving.json"
        old = {"schema": 2, "history": [
            {"git_sha": "abc", "rows": [{"label": "dense",
                                         "cache_hbm_bytes": 100}]},
        ]}
        path.write_text(json.dumps(old))
        doc = st.append_history(
            {"git_sha": "def", "mesh": {"dp": 2, "tp": 2, "devices": 4},
             "rows": []},
            path=str(path),
        )
        assert doc["schema"] == st.BENCH_SCHEMA == 8
        migrated, fresh = doc["history"]
        assert migrated["mesh"] == {"dp": 1, "tp": 1, "devices": 1}
        assert migrated["rows"][0]["per_device_cache_bytes"] == 100
        # Schema 3 -> 4: pre-pipeline rows ran the serial loop (depth 1)
        # with no device-wait/host breakdown recorded.
        assert migrated["rows"][0]["pipeline_depth"] == 1
        assert migrated["rows"][0]["step_device_wait_ms"] is None
        # Schema 4 -> 5: pre-auditor entries carry a null contract stamp.
        assert migrated["audit"] is None
        # Schema 5 -> 6: pre-observability entries carry null telemetry
        # and roofline blocks.
        assert migrated["telemetry"] is None
        assert migrated["roofline"] is None
        # Schema 6 -> 7: pre-scheduler rows ran worst-case admission with
        # no live-occupancy or preemption accounting.
        assert migrated["rows"][0]["admission_policy"] == "worst_case"
        assert migrated["rows"][0]["occupancy_live_frac"] is None
        assert migrated["rows"][0]["preempt_count"] == 0
        # Schema 7 -> 8: pre-fault-tolerance entries carry a null faults
        # rollup (the engine ran with no injection surface at all).
        assert migrated["faults"] is None
        assert fresh["mesh"]["dp"] == 2
