"""Launch-layer tests: input specs, HLO collective parsing, sharding rules,
rank budgeting for deployment plans."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPE_CASES, applicable_shapes, get_config
from repro.configs.registry import ASSIGNED
from repro.launch.hlo_stats import collective_stats
from repro.models.api import input_specs
from repro.parallel.sharding import param_pspec


class TestInputSpecs:
    @pytest.mark.parametrize("arch", sorted(ASSIGNED))
    def test_all_cells_have_specs(self, arch):
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            case = SHAPE_CASES[shape]
            specs = input_specs(cfg, case)
            assert "tokens" in specs
            if case.kind == "decode":
                assert specs["tokens"].shape == (case.global_batch, 1)
                assert specs["cache_len"].shape == (case.global_batch,)
            else:
                total = specs["tokens"].shape[1]
                if cfg.frontend == "vision":
                    total += cfg.num_patches
                assert total == case.seq_len
                assert specs["tokens"].shape[0] == case.global_batch

    def test_modality_stubs(self):
        w = input_specs(get_config("whisper-small"), SHAPE_CASES["train_4k"])
        assert w["frames"].shape == (256, 1500, 768)
        l = input_specs(get_config("llava-next-mistral-7b"), SHAPE_CASES["train_4k"])
        assert l["patches"].shape == (256, 576, 1024)


class TestHLOStats:
    def test_parses_collectives_with_trip_counts(self):
        hlo = """
HloModule test

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), replica_groups=[4,8]<=[32], to_apply=%add
  ROOT %t = tuple(...)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main () -> f32[128] {
  %w = while(...), condition=%cond, body=%body
  %ag = f32[256]{0} all-gather(f32[128]{0} %y), replica_groups={{0,1}}, dimensions={0}
  ROOT %r = f32[128] get-tuple-element(%w), index=1
}
"""
        stats = collective_stats(hlo)
        # all-reduce inside the while: counted 12x, group size 8.
        assert stats["all-reduce"]["count"] == 12
        expected_ar = 12 * 2 * 128 * 4 * (8 - 1) / 8
        assert abs(stats["all-reduce"]["wire_bytes"] - expected_ar) < 1e-6
        # all-gather at entry: counted once, group size 2.
        assert stats["all-gather"]["count"] == 1
        assert stats["all-gather"]["bytes"] == 256 * 4


class TestShardingRules:
    def test_attention_tp(self):
        leaf = jax.ShapeDtypeStruct((512, 2048), jnp.bfloat16)
        assert param_pspec(("g0", "sub0", "attn", "wq", "kernel"), leaf) == P(None, "model")
        assert param_pspec(("g0", "sub0", "attn", "wo", "kernel"), leaf) == P("model", None)

    def test_factored_input_output_sharding(self):
        """u shards its input dim, v its output dim — NEVER replicated
        (boundary inheritance replicated u for column-parallel layers;
        measured 2.7x dense bytes — §Perf C1)."""
        u = jax.ShapeDtypeStruct((2048, 128), jnp.bfloat16)
        v = jax.ShapeDtypeStruct((128, 512), jnp.bfloat16)
        assert param_pspec(("mlp", "wo", "u"), u) == P("model", None)
        assert param_pspec(("mlp", "wo", "v"), v) == P(None, "model")
        assert param_pspec(("mlp", "wi", "u"), u) == P("model", None)
        assert param_pspec(("mlp", "wi", "v"), v) == P(None, "model")
        # Tiny replicated linears stay replicated when factored.
        assert param_pspec(("attn", "wkv_a", "u"), u) == P(None, None)

    def test_experts_ep(self):
        leaf = jax.ShapeDtypeStruct((64, 2048, 1408), jnp.bfloat16)
        spec = param_pspec(("g1", "sub0", "moe", "experts", "wi", "kernel"), leaf)
        assert spec == P("model", None, None)

    def test_stacked_prefix_nones(self):
        leaf = jax.ShapeDtypeStruct((47, 64, 2048, 1408), jnp.bfloat16)
        spec = param_pspec(("g1", "sub0", "moe", "experts", "wi", "kernel"), leaf)
        assert spec == P(None, "model", None, None)

    def test_fsdp_adds_dp_axis(self):
        leaf = jax.ShapeDtypeStruct((8192, 22016), jnp.bfloat16)
        spec = param_pspec(("mlp", "wi", "kernel"), leaf, fsdp_axes=("data",))
        assert spec == P("data", "model")

    def test_rwkv_rules(self):
        leaf = jax.ShapeDtypeStruct((2048, 7168), jnp.bfloat16)
        assert param_pspec(("rwkv_c", "wk", "kernel"), leaf) == P(None, "model")
        leaf2 = jax.ShapeDtypeStruct((7168, 2048), jnp.bfloat16)
        assert param_pspec(("rwkv_c", "wv", "kernel"), leaf2) == P("model", None)


class TestRankBudget:
    def test_mxu_aligned_ranks(self):
        from repro.core import rank_for_ratio

        k = rank_for_ratio(8192, 22016, 0.3, multiple_of=128)
        assert k % 128 == 0
        assert (8192 + 22016) * k <= 0.7 * 8192 * 22016

    def test_compressed_shapes_plan(self):
        from repro.launch.compress_shapes import compressed_param_shapes
        from repro.models import build_model, param_specs

        cfg = get_config("chatglm3-6b")
        model = build_model(cfg)
        shapes = param_specs(cfg)
        cshapes = compressed_param_shapes(model, shapes, 0.3)
        import numpy as np

        dense = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        comp = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(cshapes))
        assert comp < 0.78 * dense  # ~30% removed from the compressible set
