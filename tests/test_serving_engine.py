"""Serving-engine regression tests: slot reuse across admissions, batched
vs. sequential greedy equivalence, prefill bucket compile counts, and the
one-transfer-per-step contract."""

from unittest import mock

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.serving.engine import ServingEngine

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-serve", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _solo(model, params, prompt, max_new, max_len=64):
    eng = ServingEngine(model, params, max_batch=1, max_len=max_len)
    uid = eng.submit(prompt, max_new_tokens=max_new)
    return eng.run()[uid]


class TestSlotReuse:
    def test_new_request_does_not_see_previous_occupants_kv(self, tiny_lm):
        """A slot freed by a finished request must be fully re-initialized:
        the next occupant's generations must match a fresh single-request
        run (stale KV rows from the previous occupant would change them)."""
        model, params = tiny_lm
        rng = np.random.default_rng(1)
        long_p = rng.integers(2, 200, size=13)   # larger bucket, fills rows
        short_p = rng.integers(2, 200, size=5)

        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        uid_a = eng.submit(long_p, max_new_tokens=6)
        uid_b = eng.submit(short_p, max_new_tokens=6)  # reuses slot 0
        out = eng.run()
        assert out[uid_b] == _solo(model, params, short_p, 6)
        assert out[uid_a] == _solo(model, params, long_p, 6)

    def test_mid_flight_admission_matches_solo(self, tiny_lm):
        """Requests admitted into a slot mid-flight (while another row keeps
        decoding) generate the same greedy tokens as a solo run."""
        model, params = tiny_lm
        rng = np.random.default_rng(2)
        prompts = [rng.integers(2, 200, size=n) for n in (6, 6, 7, 5)]
        lens = [9, 3, 5, 4]  # staggered finish -> slots free mid-flight

        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, lens)]
        out = eng.run()
        for uid, p, m in zip(uids, prompts, lens):
            assert out[uid] == _solo(model, params, p, m), uid


class TestBatchedSampling:
    def test_batched_matches_sequential_at_temp0(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(3)
        prompts = [rng.integers(2, 200, size=6) for _ in range(5)]
        eng = ServingEngine(model, params, max_batch=3, max_len=64)
        uids = [eng.submit(p, max_new_tokens=8) for p in prompts]
        out = eng.run()
        for uid, p in zip(uids, prompts):
            assert out[uid] == _solo(model, params, p, 8)

    def test_temperature_sampling_reproducible_and_in_vocab(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(4)
        prompts = [rng.integers(2, 200, size=6) for _ in range(3)]

        def once():
            eng = ServingEngine(model, params, max_batch=2, max_len=64, seed=9)
            uids = [eng.submit(p, max_new_tokens=6, temperature=0.7)
                    for p in prompts]
            out = eng.run()
            return [out[u] for u in uids]

        a, b = once(), once()
        assert a == b
        assert all(0 <= t < VOCAB for toks in a for t in toks)


class TestPrefillBuckets:
    """Bucketed prefill is the DENSE-slab admission path (attention models
    default to the paged engine, whose fixed-shape chunked prefill compiles
    exactly once — see test_paged_kvcache.py); pin paged=False here."""

    def test_compilations_bounded_by_buckets_not_lengths(self, tiny_lm):
        """Prompts of lengths {7, 9, 250} span two power-of-two buckets
        (16 and 256): the prefill step must compile at most twice."""
        model, params = tiny_lm
        rng = np.random.default_rng(5)
        eng = ServingEngine(model, params, max_batch=2, max_len=512,
                            paged=False)
        for n in (7, 9, 250):
            eng.submit(rng.integers(2, 200, size=n), max_new_tokens=2)
        out = eng.run()
        assert len(out) == 3
        n_buckets_used = len({eng._bucket(n) for n in (7, 9, 250)})
        assert n_buckets_used == 2
        assert eng._prefill._cache_size() <= n_buckets_used

    def test_same_bucket_requests_prefill_together(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(6)
        eng = ServingEngine(model, params, max_batch=4, max_len=64,
                            paged=False)
        for n in (5, 7, 9, 11):  # all bucket 16
            eng.submit(rng.integers(2, 200, size=n), max_new_tokens=2)
        eng.run()
        assert eng._prefill._cache_size() == 1


class TestSubmitValidation:
    def test_rejects_empty_prompt(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        with pytest.raises(ValueError, match="empty"):
            eng.submit(np.array([], np.int32))

    def test_rejects_oversized_prompt(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        with pytest.raises(ValueError, match="exceeds"):
            eng.submit(np.arange(2, 2 + 80))


class TestPadSensitiveFallback:
    def test_moe_models_do_not_bucket(self):
        """Token-choice MoE budgets expert capacity over the flattened
        token batch: right-padded prompts would evict real tokens from
        expert slots, so MoE engines must use exact-length prefill."""
        from repro.configs import get_config

        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        assert not eng._bucketed
        rng = np.random.default_rng(8)
        uid = eng.submit(rng.integers(2, 200, size=6), max_new_tokens=3)
        out = eng.run()
        assert len(out[uid]) == 3

    def test_recurrent_models_do_not_bucket(self):
        from repro.configs import get_config

        cfg = get_config("rwkv6-1.6b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        assert not eng._bucketed

    def test_attention_models_bucket(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            paged=False)
        assert eng._bucketed

    def test_attention_models_default_to_paged(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        assert eng.paged

    def test_pad_sensitive_models_default_to_dense(self):
        from repro.configs import get_config

        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        assert not eng.paged
        with pytest.raises(ValueError, match="cache layout"):
            ServingEngine(model, params, max_batch=2, max_len=64, paged=True)


class TestSyncFreeDecode:
    def test_exactly_one_device_to_host_transfer_per_step(self, tiny_lm):
        """Depth-1 pipeline == today's unpipelined engine: every step() is
        one dispatch followed by exactly one consumed transfer."""
        model, params = tiny_lm
        rng = np.random.default_rng(7)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            pipeline_depth=1)
        for _ in range(2):
            eng.submit(rng.integers(2, 200, size=6), max_new_tokens=8)
        eng._admit()

        real = jax.device_get
        calls = []

        def counting(x):
            calls.append(1)
            return real(x)

        with mock.patch.object(jax, "device_get", side_effect=counting):
            for _ in range(4):
                eng.step()
        assert len(calls) == 4  # one transfer per decode step, not per slot

    def test_pipelined_steps_consume_at_most_one_transfer(self, tiny_lm):
        """Depth 2: the first step only dispatches (no sync at all); every
        later step consumes exactly the one oldest transfer, and drain()
        flushes the remaining in-flight step."""
        model, params = tiny_lm
        rng = np.random.default_rng(7)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            pipeline_depth=2)
        for _ in range(2):
            eng.submit(rng.integers(2, 200, size=6), max_new_tokens=8)
        eng._admit()

        real = jax.device_get
        calls = []

        def counting(x):
            calls.append(1)
            return real(x)

        with mock.patch.object(jax, "device_get", side_effect=counting):
            per_step = []
            for _ in range(4):
                before = len(calls)
                eng.step()
                per_step.append(len(calls) - before)
            eng.drain()
        assert per_step == [0, 1, 1, 1]  # device runs one step ahead
        assert len(calls) == 4  # drain syncs the ring's last entry

    def test_transfer_counter_tracks_steps(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        eng.submit(np.arange(2, 8), max_new_tokens=5)
        eng.run()
        assert eng.decode_transfers == len(eng.step_times)
