"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model
from repro.models.losses import next_token_xent

ARCHS = sorted(ASSIGNED)


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


def _batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
    elif cfg.frontend == "vision":
        from repro.models.transformer import VISION_FEATURE_DIM

        batch["patches"] = jax.random.normal(key, (b, cfg.num_patches, VISION_FEATURE_DIM))
    return batch


def _apply(model, params, batch, **kw):
    cfg = model.cfg
    if cfg.is_encdec:
        return model.apply(params, batch["tokens"], frames=batch.get("frames"), **kw)
    return model.apply(params, batch["tokens"], patches=batch.get("patches"), **kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)
    logits, _, aux = _apply(model, params, batch, mode="train")
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch} produced non-finite logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg)

    def loss_fn(p):
        logits, _, aux = _apply(model, p, batch, mode="train")
        return next_token_xent(logits, batch["tokens"]) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm), f"{arch} grad norm not finite"
    # One SGD step must change the loss (graph is actually connected).
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params)
    assert jnp.isfinite(loss2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, rng):
    """Decode-with-cache must agree with the full causal forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    b, s = 2, 8
    batch = _batch(cfg, b=b, s=s)
    tokens = batch["tokens"]

    # Full forward over the whole sequence.
    full_logits, _, _ = _apply(model, params, batch, mode="train")

    # Prefill on the first s-1 tokens, then decode token s-1.
    max_len = 32
    cache = model.init_cache(b, max_len)
    pre = dict(batch)
    pre["tokens"] = tokens[:, : s - 1]
    logits_p, cache, _ = _apply(model, params, pre, mode="prefill", cache=cache)
    if cfg.frontend == "vision":
        n_prefix = cfg.num_patches
    else:
        n_prefix = 0
    cache_len = jnp.full((b,), s - 1 + n_prefix, jnp.int32)
    dec = {"tokens": tokens[:, s - 1 : s]}
    if cfg.is_encdec:
        logits_d, _, _ = model.apply(
            params, dec["tokens"], mode="decode", cache=cache, cache_len=cache_len
        )
    else:
        logits_d, _, _ = model.apply(
            params, dec["tokens"], mode="decode", cache=cache, cache_len=cache_len
        )
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]),
        np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_compressible_targets_resolve(arch, rng):
    """Every TargetSpec path must exist in the param tree with right shape."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    for t in model.compressible_targets():
        node = shapes
        for p in t.path:
            assert p in node, f"{arch}: missing {t.path}"
            node = node[p]
        kern = node["kernel"]
        expected = (*t.stacked, t.in_dim, t.out_dim)
        assert tuple(kern.shape) == expected, (
            f"{arch}: {t.name} shape {kern.shape} != {expected}"
        )


def test_shape_case_applicability():
    from repro.configs import applicable_shapes

    subq = {a for a in ARCHS if get_config(a).subquadratic}
    assert subq == {"jamba-v0.1-52b", "rwkv6-1.6b"}
    for a in ARCHS:
        shapes = applicable_shapes(get_config(a))
        assert ("long_500k" in shapes) == (a in subq)
