"""Regression-sentinel tests: a seeded regression must trip it.

The sentinel only compares the NEWEST history entry against PRIOR entries
at the same config hash (and mesh, for serving) — cross-machine absolute
numbers never meet in one comparison.  These tests seed synthetic
histories with a known tok/s drop and a known perplexity rise and pin the
nonzero exit; the committed BENCH_serving.json / BENCH_quality.json must
pass, since CI runs the sentinel on them after every append."""

import json
import os

import pytest

from benchmarks.sentinel import (
    DEFAULT_PPL_THRESHOLD,
    DEFAULT_TOK_THRESHOLD,
    QUALITY_PATH,
    SERVING_PATH,
    check_quality,
    check_serving,
    load_history,
    main,
    run_sentinel,
)

MESH = {"dp": 1, "tp": 1, "devices": 1}


def serving_entry(tok_paged=100.0, tok_spec=150.0, config_hash="cfgA",
                  mesh=MESH, sha="aaa"):
    return {
        "git_sha": sha,
        "config_hash": config_hash,
        "mesh": dict(mesh),
        "summary": {
            "tok_per_s_paged": tok_paged,
            "tok_per_s_spec": tok_spec,
        },
    }


def quality_entry(ppl=None, config_hash="cfgQ", sha="aaa"):
    return {
        "git_sha": sha,
        "config_hash": config_hash,
        "compressed_ppl": dict(ppl or {"en_a": 30.0, "zh": 45.0}),
    }


def write_doc(path, entries):
    with open(path, "w") as f:
        json.dump({"schema": 1, "history": entries}, f)


# ------------------------------------------------------------- serving side


def test_serving_regression_detected():
    hist = [serving_entry(tok_paged=100.0),
            serving_entry(tok_paged=70.0, sha="bbb")]  # 0.7 < 0.8 bar
    findings = check_serving(hist)
    assert len(findings) == 1
    f = findings[0]
    assert f["metric"] == "tok_per_s_paged"
    assert f["ratio"] == pytest.approx(0.7)
    assert f["git_sha"] == "bbb"


def test_serving_within_threshold_passes():
    hist = [serving_entry(tok_paged=100.0),
            serving_entry(tok_paged=85.0, sha="bbb")]
    assert check_serving(hist) == []


def test_serving_best_prior_is_the_bar():
    # A slow middle run must not lower the bar set by the best prior.
    hist = [serving_entry(tok_paged=100.0),
            serving_entry(tok_paged=60.0, sha="mid"),
            serving_entry(tok_paged=75.0, sha="new")]
    findings = check_serving(hist)
    assert [f["metric"] for f in findings] == ["tok_per_s_paged"]
    assert findings[0]["baseline"] == 100.0


def test_serving_mismatched_config_or_mesh_is_not_compared():
    hist = [serving_entry(tok_paged=100.0, config_hash="other"),
            serving_entry(tok_paged=10.0, sha="bbb")]
    assert check_serving(hist) == []
    hist = [serving_entry(tok_paged=100.0,
                          mesh={"dp": 2, "tp": 2, "devices": 4}),
            serving_entry(tok_paged=10.0, sha="bbb")]
    assert check_serving(hist) == []


# ------------------------------------------------------------- quality side


def test_quality_regression_detected():
    hist = [quality_entry({"en_a": 30.0, "zh": 45.0}),
            quality_entry({"en_a": 36.0, "zh": 45.0}, sha="bbb")]  # 1.2x
    findings = check_quality(hist)
    assert len(findings) == 1
    assert findings[0]["metric"] == "compressed_ppl/en_a"
    assert findings[0]["ratio"] == pytest.approx(1.2)


def test_quality_within_threshold_passes():
    hist = [quality_entry({"en_a": 30.0}),
            quality_entry({"en_a": 31.0}, sha="bbb")]
    assert check_quality(hist) == []


def test_quality_lowest_prior_is_the_bar():
    hist = [quality_entry({"en_a": 30.0}),
            quality_entry({"en_a": 50.0}, sha="mid"),
            quality_entry({"en_a": 34.0}, sha="new")]  # 34 > 1.1 * 30
    findings = check_quality(hist)
    assert len(findings) == 1
    assert findings[0]["baseline"] == 30.0


def test_no_baseline_passes_vacuously():
    assert check_serving([serving_entry()]) == []
    assert check_quality([quality_entry()]) == []
    assert check_serving([]) == [] and check_quality([]) == []


# ------------------------------------------------------------ CLI / end2end


def test_cli_exit_codes(tmp_path):
    sp = tmp_path / "BENCH_serving.json"
    qp = tmp_path / "BENCH_quality.json"
    write_doc(sp, [serving_entry(100.0), serving_entry(95.0, sha="bbb")])
    write_doc(qp, [quality_entry(), quality_entry(sha="bbb")])
    assert main(["--serving", str(sp), "--quality", str(qp)]) == 0

    write_doc(sp, [serving_entry(100.0), serving_entry(50.0, sha="bbb")])
    assert main(["--serving", str(sp), "--quality", str(qp)]) == 1
    # tightened quality threshold trips on an otherwise-passing history
    write_doc(sp, [serving_entry(100.0)])
    write_doc(qp, [quality_entry({"en_a": 30.0}),
                   quality_entry({"en_a": 31.5}, sha="bbb")])
    assert main(["--serving", str(sp), "--quality", str(qp),
                 "--ppl-threshold", "1.01"]) == 1


def test_cli_json_output(tmp_path, capsys):
    sp = tmp_path / "s.json"
    write_doc(sp, [serving_entry(100.0), serving_entry(10.0, sha="bbb")])
    rc = main(["--serving", str(sp),
               "--quality", str(tmp_path / "missing.json"), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1 and out["ok"] is False
    assert out["findings"][0]["kind"] == "serving"


def test_run_sentinel_missing_files(tmp_path):
    ok, findings, ctx = run_sentinel(str(tmp_path / "a.json"),
                                     str(tmp_path / "b.json"))
    assert ok and findings == []
    assert ctx["serving_entries"] == ctx["quality_entries"] == 0


def test_committed_histories_pass():
    """The repo's own bench histories must satisfy the sentinel — CI runs
    it on them after every append."""
    assert os.path.exists(SERVING_PATH), "BENCH_serving.json missing"
    ok, findings, ctx = run_sentinel()
    assert ok, f"committed bench history regressed: {findings}"
    assert ctx["serving_entries"] >= 1
    assert 0 < DEFAULT_TOK_THRESHOLD < 1 < DEFAULT_PPL_THRESHOLD


def test_committed_serving_history_well_formed():
    hist = load_history(SERVING_PATH)
    assert hist, "serving history unreadable"
    # Entries older than the stamping scheme may lack the hash (they just
    # never match a comparison); everything recent must carry it.
    assert "config_hash" in hist[-1] and "git_sha" in hist[-1]
    if os.path.exists(QUALITY_PATH):
        qhist = load_history(QUALITY_PATH)
        assert qhist
        for e in qhist:
            assert "config_hash" in e and "compressed_ppl" in e
