"""Integration tests: calibration -> compression -> eval -> serving, and
the fault-tolerant train loop with resume.  All on tiny CPU models."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.calib.runner import calibration_batches, collect_grams
from repro.configs import get_config
from repro.configs.paper_models import small_lm
from repro.core import CompressionConfig, build_plan, compress_params
from repro.eval.perplexity import eval_batches, evaluate_ppl
from repro.models import build_model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny", vocab_size=VOCAB, num_layers=2, d_model=64,
                   d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def tiny_grams(tiny_lm):
    model, params = tiny_lm
    return collect_grams(
        model, params,
        calibration_batches(VOCAB, "en_a", n_samples=64, batch=8, seq=32),
    )


class TestCompressionPipeline:
    @pytest.mark.parametrize("method", ["svd", "asvd0", "asvd1", "asvd2", "nsvd1", "nid1"])
    def test_compress_eval_finite(self, tiny_lm, tiny_grams, method):
        model, params = tiny_lm
        cfg = CompressionConfig(method=method, ratio=0.2, dtype="float32",
                                use_randomized=False)
        plan = build_plan(model.compressible_targets(), cfg)
        cparams = compress_params(params, plan, tiny_grams)
        ppl = evaluate_ppl(model, cparams, eval_batches(VOCAB, "en_a", n_batches=2, batch=4, seq=32))
        assert np.isfinite(ppl) and ppl > 1.0

    def test_achieved_ratio_close(self, tiny_lm):
        model, params = tiny_lm
        for ratio in (0.2, 0.4):
            plan = build_plan(
                model.compressible_targets(),
                CompressionConfig(method="svd", ratio=ratio),
            )
            assert plan.achieved_ratio >= ratio - 0.02

    def test_compressed_param_count_matches_plan(self, tiny_lm, tiny_grams):
        model, params = tiny_lm
        cfg = CompressionConfig(method="nsvd1", ratio=0.3, dtype="float32",
                                use_randomized=False)
        plan = build_plan(model.compressible_targets(), cfg)
        cparams = compress_params(params, plan, tiny_grams)
        dense_n = sum(x.size for x in jax.tree.leaves(params))
        comp_n = sum(x.size for x in jax.tree.leaves(cparams))
        target_names = {t.name for t in plan.targets}
        # Only targeted matrices shrink; overall must drop accordingly.
        assert comp_n < dense_n

    def test_nested_params_structure(self, tiny_lm, tiny_grams):
        model, params = tiny_lm
        cfg = CompressionConfig(method="nsvd1", ratio=0.3, k1_frac=0.9,
                                dtype="float32", use_randomized=False)
        plan = build_plan(model.compressible_targets(), cfg)
        cparams = compress_params(params, plan, tiny_grams)
        t = plan.targets[0]
        node = cparams
        for p in t.path:
            node = node[p]
        assert set(node) == {"u", "v", "u2", "v2"}
        k = plan.rank_of(t)
        assert node["u"].shape[-1] + node["u2"].shape[-1] == k

    def test_gram_keys_cover_targets(self, tiny_lm, tiny_grams):
        """Every compression target must find its Gram (per-layer or
        fallback) in the calibration store."""
        model, _ = tiny_lm
        for t in model.compressible_targets():
            g = tiny_grams.gram(t.gram_key + "/0" if t.stacked else t.gram_key,
                                fallback=t.gram_key)
            assert g.shape == (t.in_dim, t.in_dim)


@pytest.mark.slow
class TestMoECalibration:
    def test_per_expert_grams_collected(self):
        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        store = collect_grams(
            model, params,
            calibration_batches(cfg.vocab_size, "en_a", n_samples=32, batch=4, seq=16),
        )
        expert_keys = [k for k in store.keys() if "expert_buf/" in k]
        assert expert_keys, "no per-expert grams collected"
        # Compression with per-expert grams must run end to end.
        plan = build_plan(
            model.compressible_targets(),
            CompressionConfig(method="nsvd1", ratio=0.2, dtype="float32",
                              use_randomized=False, min_dim=8),
        )
        cparams = compress_params(params, plan, store)
        logits, _, _ = model.apply(
            params=cparams,
            tokens=jnp.zeros((1, 8), jnp.int32),
            mode="train",
        )
        assert jnp.isfinite(logits).all()


class TestServingEngine:
    def test_batched_serving_matches_sequential_greedy(self, tiny_lm):
        from repro.serving.engine import ServingEngine

        model, params = tiny_lm
        rng = np.random.default_rng(0)
        prompts = [rng.integers(2, 200, size=6) for _ in range(5)]

        eng = ServingEngine(model, params, max_batch=2, max_len=64)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        out = eng.run()
        assert len(out) == 5
        # Sequential single-request reference.
        for i, p in enumerate(prompts):
            eng1 = ServingEngine(model, params, max_batch=1, max_len=64)
            uid = eng1.submit(p, max_new_tokens=8)
            ref = eng1.run()[uid]
            assert out[i] == ref, f"request {i}: batched != sequential"


@pytest.mark.slow
class TestTrainLoopResume:
    def test_checkpoint_resume_bitwise_data(self, tmp_path):
        from repro.launch.train import train_loop

        d = str(tmp_path / "ck")
        train_loop(arch="small-llama", steps=6, batch=2, seq=32,
                   ckpt_dir=d, ckpt_every=3)
        # Resume and extend.
        params, _, metrics = train_loop(arch="small-llama", steps=9, batch=2,
                                        seq=32, ckpt_dir=d, ckpt_every=3)
        assert np.isfinite(float(metrics["loss"]))

    def test_grad_compress_trains(self):
        from repro.launch.train import train_loop

        _, _, metrics = train_loop(arch="small-llama", steps=4, batch=2,
                                   seq=32, grad_compress=True)
        assert np.isfinite(float(metrics["loss"]))
