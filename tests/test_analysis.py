"""Static contract auditor tests.

Two halves, mirroring the auditor's job description:

  * the REAL serving roots (both cache layouts, meshless and on a (2, 2)
    DP x TP mesh) pass every audit — transfer contract, donation aliasing,
    sharding pins, dtype lint, Pallas VMEM lint, allocator interleavings;
  * each audit class CATCHES a deliberately broken root: a dropped
    donation, an extra D2H output, a drifted sharding pin, a large fp32
    upcast, an oversized VMEM tile, and each injected allocator bug.

The (2, 2) tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the static-analysis CI job sets it); elsewhere they skip."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    audit_donation,
    audit_dtypes,
    audit_roots,
    audit_sharding,
    audit_transfers,
    check_interleavings,
    kernel_lint,
)
from repro.analysis.interleave import BUGS
from repro.analysis.pallas_lint import serving_kernel_lints
from repro.analysis.roots import make_root_context, trace_root
from repro.configs.paper_models import small_lm
from repro.launch.mesh import make_serving_mesh
from repro.launch.steps import RootSpec
from repro.models import build_model
from repro.models.api import param_specs
from repro.parallel.sharding import make_parallelism

need4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)


@pytest.fixture(scope="module")
def tiny():
    cfg = small_lm(name="tiny-audit", vocab_size=256, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    return cfg, model, param_specs(cfg)


def _audit_all(model, avals, layout, par=None):
    arts = audit_roots(model, avals, par=par, layout=layout, spec=True,
                       max_batch=4, max_len=64, bucket=8)
    assert arts, "registry returned no roots"
    for art in arts:
        tr = audit_transfers(art)
        assert tr.ok, f"{art.name}: {tr.notes}"
        dn = audit_donation(art)
        assert dn.ok, f"{art.name}: {dn.missing or dn.notes}"
        sh = audit_sharding(art)
        assert sh.ok, f"{art.name}: {sh.mismatches}"
        dt = audit_dtypes(art)
        assert dt.ok, f"{art.name}: {dt.f64_ops + dt.large_upcasts}"
        if par is not None:
            assert not sh.skipped and sh.checked_leaves > 0
    return arts


class TestRealRootsPass:
    def test_dense_meshless(self, tiny):
        _, model, avals = tiny
        _audit_all(model, avals, "dense")

    def test_paged_meshless(self, tiny):
        _, model, avals = tiny
        arts = _audit_all(model, avals, "paged")
        names = {a.name for a in arts}
        assert {"paged_decode", "paged_prefill_chunk", "spec_draft",
                "spec_verify", "draft_prefill"} <= names

    @need4
    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_meshed_2x2(self, tiny, layout):
        _, model, avals = tiny
        par = make_parallelism(make_serving_mesh(2, 2))
        _audit_all(model, avals, layout, par=par)

    def test_steady_roots_emit_one_small_d2h(self, tiny):
        _, model, avals = tiny
        for art in audit_roots(model, avals, layout="paged", spec=True,
                               max_batch=4, max_len=64, bucket=8):
            tr = audit_transfers(art)
            if art.spec.kind == "steady":
                assert len(tr.d2h_outputs) == 1
                # tokens-per-row scale, not a logits matrix
                assert tr.d2h_bytes <= 4 * 4 * (art.ctx.spec_k + 3)


# --------------------------------------------------- seeded-violation half

def _toy_spec(build, abstract_inputs, *, donate=(), d2h=(0,),
              kind="steady", name="toy"):
    return RootSpec(name=name, layout="dense", kind=kind, donate=donate,
                    d2h=d2h, build=build, abstract_inputs=abstract_inputs,
                    shardings=lambda sh, ctx, dp=None: (None, None))


def _toy_ctx(model):
    return make_root_context(model, max_batch=4, max_len=64)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


class TestSeededViolations:
    def test_dropped_donation_caught(self, tiny):
        _, model, _ = tiny
        # state (arg 1) is donated but RESHAPED before output: no output
        # buffer is shape-compatible, so the alias silently drops.
        spec = _toy_spec(
            lambda ctx: lambda x, state: (x * 2, state.reshape(8, 8).T),
            lambda ctx, avals: (_sds((4,), jnp.float32),
                                _sds((64,), jnp.float32)),
            donate=(1,), name="dropped_donation")
        art = trace_root(spec, _toy_ctx(model), None)
        dn = audit_donation(art)
        assert not dn.ok
        assert dn.actual_aliases < dn.expected_aliases

    def test_good_donation_passes(self, tiny):
        _, model, _ = tiny
        spec = _toy_spec(
            lambda ctx: lambda x, state: (x * 2, state + 1),
            lambda ctx, avals: (_sds((4,), jnp.float32),
                                _sds((64,), jnp.float32)),
            donate=(1,), name="good_donation")
        assert audit_donation(trace_root(spec, _toy_ctx(model), None)).ok

    def test_extra_d2h_caught(self, tiny):
        _, model, _ = tiny
        # A steady root declaring two host readbacks per step.
        spec = _toy_spec(
            lambda ctx: lambda x: (x * 2, x * 3),
            lambda ctx, avals: (_sds((4,), jnp.float32),),
            d2h=(0, 1), kind="steady", name="extra_d2h")
        tr = audit_transfers(trace_root(spec, _toy_ctx(model), None))
        assert not tr.ok and "exactly one" in " ".join(tr.notes)

    def test_draft_d2h_caught(self, tiny):
        _, model, _ = tiny
        spec = _toy_spec(
            lambda ctx: lambda x: (x * 2,),
            lambda ctx, avals: (_sds((4,), jnp.float32),),
            d2h=(0,), kind="draft", name="draft_d2h")
        assert not audit_transfers(trace_root(spec, _toy_ctx(model), None)).ok

    @need4
    def test_sharding_drift_caught(self, tiny):
        from jax.sharding import NamedSharding, PartitionSpec as P

        _, model, _ = tiny
        par = make_parallelism(make_serving_mesh(2, 2))
        mesh = par.mesh
        rep = NamedSharding(mesh, P())
        row = NamedSharding(mesh, P("data"))
        # Compile with replicated outputs but EXPECT row-sharded: the audit
        # must flag the drift rather than trust the pin.
        spec = dataclasses.replace(
            _toy_spec(
                lambda ctx: lambda x: (x * 2,),
                lambda ctx, avals: (_sds((4, 8), jnp.float32),),
                d2h=(0,), name="drifted"),
            shardings=lambda sh, ctx, dp=None: ((rep,), (rep,)))
        art = trace_root(spec, _toy_ctx(model), None,
                         sh=object())  # sh only gates the hook call
        # Overwrite the recorded expectation with the WRONG pin.
        art = dataclasses.replace(art, expected_shardings=((row,), (row,)))
        sh_audit = audit_sharding(art)
        assert not sh_audit.ok and sh_audit.mismatches

    def test_fp32_leak_caught(self, tiny):
        _, model, _ = tiny
        spec = _toy_spec(
            lambda ctx: lambda w: (jnp.sum(w.astype(jnp.float32)),),
            lambda ctx, avals: (_sds((512, 512), jnp.bfloat16),),
            d2h=(0,), name="fp32_leak")
        art = trace_root(spec, _toy_ctx(model), None)
        dt = audit_dtypes(art, upcast_threshold=1024)
        assert not dt.ok and dt.large_upcasts

    def test_small_upcast_passes(self, tiny):
        _, model, _ = tiny
        spec = _toy_spec(
            lambda ctx: lambda w: (jnp.sum(w.astype(jnp.float32)),),
            lambda ctx, avals: (_sds((4, 8), jnp.bfloat16),),
            d2h=(0,), name="softmax_upcast")
        art = trace_root(spec, _toy_ctx(model), None)
        assert audit_dtypes(art, upcast_threshold=1024).ok

    def test_oversized_vmem_tile_caught(self):
        lint = kernel_lint("huge", [
            {"name": "monster", "shape": (4096, 4096), "dtype": "float32",
             "buffers": 2},
        ])
        assert not lint.ok and lint.vmem_bytes > lint.vmem_limit

    def test_unaligned_tile_flagged(self):
        lint = kernel_lint("ragged", [
            {"name": "odd", "shape": (7, 130), "dtype": "bfloat16",
             "buffers": 1},
        ])
        assert lint.ok  # fits...
        assert lint.misaligned  # ...but pays padding


class TestPallasLint:
    def test_serving_kernels_fit(self, tiny):
        cfg, _, _ = tiny
        lints = serving_kernel_lints(cfg, max_batch=4, max_len=64)
        assert {l.kernel for l in lints} >= {"nested_lowrank", "gram"}
        for lint in lints:
            assert lint.ok, f"{lint.kernel}: {lint.vmem_bytes} bytes"

    def test_dispatch_gate_matches_lint(self):
        # The ops.py VMEM gate and the lint arithmetic share one estimator:
        # a rank that the gate rejects must also be over the lint budget.
        from repro.kernels.nested_lowrank.nested_lowrank import (
            VMEM_LIMIT_BYTES,
            kernel_vmem_bytes,
        )
        small = kernel_vmem_bytes(8, 512, 1024, 64, 32)
        huge = kernel_vmem_bytes(8, 4096, 11008, 2400, 1200)
        assert small <= VMEM_LIMIT_BYTES < huge


class TestInterleave:
    def test_clean_allocator_passes(self):
        report = check_interleavings()
        assert report.ok
        assert report.states_explored > 100

    @pytest.mark.parametrize("bug", BUGS)
    def test_injected_bugs_caught(self, bug):
        report = check_interleavings(bug=bug, max_ops=6)
        assert not report.ok, f"checker missed injected bug {bug!r}"
