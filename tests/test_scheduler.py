"""Continuous-batching scheduler tests: admission-policy stream pins,
preemption/swap/stall correctness under pool pressure, SLA priority +
aging + placement units, decode-row packing invariance, scheduler
observability, and the BENCH schema-7 migration."""

import json

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (
    ADMISSION_POLICIES,
    RESUME_MODES,
    Scheduler,
    SchedulerConfig,
)

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-sched", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _solo(model, params, prompt, max_new, max_len=64, temperature=0.0,
          seed=0):
    eng = ServingEngine(model, params, max_batch=1, max_len=max_len,
                        seed=seed)
    uid = eng.submit(prompt, max_new_tokens=max_new,
                     temperature=temperature)
    return eng.run()[uid]


def _prompts(seed, n, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 200, size=int(rng.integers(lo, hi)))
            for _ in range(n)]


# ----------------------------------------------------- config validation


class TestSchedulerConfig:
    def test_defaults(self):
        cfg = SchedulerConfig()
        assert cfg.admission == "on_demand" and cfg.preempt
        assert cfg.resume == "reprefill"
        assert cfg.priority_classes == ("default",)

    @pytest.mark.parametrize("kw", [
        {"admission": "lazy"},
        {"resume": "restart"},
        {"priority_classes": ()},
        {"priority_classes": ("a", "a")},
        {"aging_rounds": -1},
    ])
    def test_rejects_bad_knobs(self, kw):
        with pytest.raises(ValueError):
            SchedulerConfig(**kw)

    def test_policy_tuples_exported(self):
        assert "on_demand" in ADMISSION_POLICIES
        assert "swap" in RESUME_MODES


# ----------------------------------------------------------- queue units


class _Req:
    def __init__(self, uid, class_idx=0, prefix=4, max_new=8, generated=()):
        self.uid = uid
        self.class_idx = class_idx
        self.prefix_len = prefix
        self.max_new_tokens = max_new
        self.generated = list(generated)


class TestQueues:
    def test_fifo_within_single_class(self):
        s = Scheduler()
        for i in range(3):
            s.submit(_Req(i, class_idx=0))
        assert [s.pop_head().uid for _ in range(3)] == [0, 1, 2]

    def test_higher_class_admits_first(self):
        s = Scheduler(SchedulerConfig(
            priority_classes=("interactive", "batch")))
        s.submit(_Req(0, class_idx=1))     # batch, submitted first
        s.submit(_Req(1, class_idx=0))     # interactive
        assert s.pop_head().uid == 1
        assert s.pop_head().uid == 0

    def test_aging_prevents_starvation(self):
        s = Scheduler(SchedulerConfig(
            priority_classes=("hi", "lo"), aging_rounds=3))
        s.submit(_Req(0, class_idx=1))
        s.submit(_Req(1, class_idx=0))
        assert s.head().uid == 1
        for _ in range(3):                 # lo's head ages one rank
            s.note_blocked()
        # equal effective rank now: the earlier-submitted lo wins the
        # seq tiebreak
        assert s.head().uid == 0

    def test_requeue_goes_to_class_front(self):
        s = Scheduler()
        s.submit(_Req(0))
        s.submit(_Req(1))
        victim = s.pop_head()
        s.requeue(victim)
        assert s.head().uid == 0

    def test_class_index_mapping_and_unknown_raises(self):
        s = Scheduler(SchedulerConfig(priority_classes=("a", "b")))
        assert s.class_index("a") == 0
        assert s.class_index(None) == 1   # lowest class
        with pytest.raises(ValueError, match="unknown latency class"):
            s.class_index("c")

    def test_take_bucket_groups_fifo(self):
        s = Scheduler()
        for uid, n in enumerate((5, 9, 6, 7)):
            s.submit(_Req(uid, prefix=n))
        group = s.take_bucket(2, lambda r: 16 if r.prefix_len < 8 else 32)
        assert [r.uid for r in group] == [0, 2]
        # non-matching requests keep FIFO order
        assert [r.uid for r in s.queued()] == [1, 3]

    def test_admit_tokens_by_policy(self):
        od = Scheduler(SchedulerConfig(admission="on_demand"))
        wc = Scheduler(SchedulerConfig(admission="worst_case"))
        r = _Req(0, prefix=10, max_new=20, generated=[1, 2, 3])
        assert od.admit_tokens(r, max_len=64) == 10
        assert wc.admit_tokens(r, max_len=64) == 10 + 17
        assert wc.admit_tokens(r, max_len=16) == 16

    def test_pick_victim_most_blocks_then_lowest_class(self):
        s = Scheduler()
        assert s.pick_victim([]) is None
        # (slot, blocks, class_idx): most blocks wins
        assert s.pick_victim([(0, 2, 0), (1, 5, 0), (2, 3, 1)]) == 1
        # blocks tie -> lower-priority (higher idx) class evicted
        assert s.pick_victim([(0, 3, 0), (1, 3, 1)]) == 1


class TestPlacementAndRowOrder:
    def test_row_order_sorts_longest_first_per_shard(self):
        s = Scheduler()
        dev_len = np.array([3, 9, 5, 2, 8, 1, 0, 4], np.int64)
        active = np.array([1, 1, 0, 1, 1, 1, 1, 1], bool)
        order = s.row_order(dev_len, active, max_batch=8, dp_shards=2)
        # shard 0 (slots 0..3): live lens 3,9,-,2 -> 1,0,3 then dead 2
        assert list(order[:4]) == [1, 0, 3, 2]
        # shard 1 (slots 4..7): lens 8,1,0,4 -> 4,7,5,6
        assert list(order[4:]) == [4, 7, 5, 6]

    def test_row_order_disabled_returns_none(self):
        s = Scheduler(SchedulerConfig(sort_decode_rows=False))
        assert s.row_order(np.zeros(4), np.ones(4, bool), 4, 1) is None


# ----------------------------------------------- policy equivalence pins


class TestPolicyStreams:
    @pytest.mark.parametrize("admission", ADMISSION_POLICIES)
    def test_policies_match_solo_without_pressure(self, tiny_lm, admission):
        """With the pool covering worst case, both admission policies
        produce the seed engine's greedy streams exactly."""
        model, params = tiny_lm
        prompts = _prompts(7, 5)
        eng = ServingEngine(
            model, params, max_batch=2, max_len=64,
            sched_config=SchedulerConfig(admission=admission))
        uids = [eng.submit(p, max_new_tokens=7) for p in prompts]
        out = eng.run()
        assert eng.scheduler_stats()["preempt_count"] == 0
        for uid, p in zip(uids, prompts):
            assert out[uid] == _solo(model, params, p, 7), uid

    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_row_sort_stream_invariance(self, tiny_lm, depth):
        """The longest-first dispatch permutation must not change any
        token at any pipeline depth."""
        model, params = tiny_lm
        prompts = _prompts(8, 6)
        lens = [9, 3, 6, 4, 8, 5]

        def run(sort):
            eng = ServingEngine(
                model, params, max_batch=3, max_len=64,
                pipeline_depth=depth,
                sched_config=SchedulerConfig(sort_decode_rows=sort))
            uids = [eng.submit(p, max_new_tokens=m)
                    for p, m in zip(prompts, lens)]
            out = eng.run()
            return [out[u] for u in uids]

        assert run(True) == run(False)


# ----------------------------------------------- preemption under pressure


class TestPreemption:
    @pytest.mark.parametrize("depth", [1, 2])
    def test_reprefill_streams_survive_preemption(self, tiny_lm, depth):
        """Pool far below worst case: victims are evicted, requeued and
        re-prefilled — greedy streams stay bit-identical to solo."""
        model, params = tiny_lm
        prompts = _prompts(9, 6, lo=4, hi=10)
        eng = ServingEngine(
            model, params, max_batch=3, max_len=64, paged=True,
            block_size=8, num_blocks=8, pipeline_depth=depth,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=True))
        uids = [eng.submit(p, max_new_tokens=16) for p in prompts]
        out = eng.run()
        stats = eng.scheduler_stats()
        assert stats["preempt_count"] > 0
        assert stats["resumes"] == stats["preempt_count"]
        for uid, p in zip(uids, prompts):
            assert out[uid] == _solo(model, params, p, 16), uid

    def test_swap_resume_preserves_temperature_streams(self, tiny_lm):
        """Swap resume restores KV blocks AND the sampling-key chain, so
        even temperature>0 streams match the uncontended run."""
        model, params = tiny_lm
        prompts = _prompts(10, 5, lo=4, hi=10)

        def run(num_blocks, resume="swap"):
            eng = ServingEngine(
                model, params, max_batch=3, max_len=64, paged=True,
                block_size=8, num_blocks=num_blocks, seed=5,
                sched_config=SchedulerConfig(admission="on_demand",
                                             preempt=True, resume=resume))
            uids = [eng.submit(p, max_new_tokens=16, temperature=0.8)
                    for p in prompts]
            out = eng.run()
            return [out[u] for u in uids], eng.scheduler_stats()

        base, base_stats = run(num_blocks=24)     # worst case covered
        assert base_stats["preempt_count"] == 0
        press, stats = run(num_blocks=8)
        assert stats["preempt_count"] > 0
        assert stats["swap_bytes"] > 0
        assert press == base

    def test_swap_unsupported_with_spec(self, tiny_lm):
        model, params = tiny_lm
        from repro.serving.spec import SpecConfig

        with pytest.raises(ValueError, match="swap"):
            ServingEngine(
                model, params, max_batch=2, max_len=64, paged=True,
                spec_config=SpecConfig(draft_params=params, k=2),
                sched_config=SchedulerConfig(resume="swap"))

    def test_priority_class_preempts_lower(self, tiny_lm):
        """A queued interactive request evicts a running batch-class
        victim when the batch is full — and both finish correctly."""
        model, params = tiny_lm
        prompts = _prompts(11, 3, lo=4, hi=8)
        eng = ServingEngine(
            model, params, max_batch=2, max_len=64, paged=True,
            block_size=8, num_blocks=16,
            sched_config=SchedulerConfig(
                admission="on_demand", preempt=True,
                priority_classes=("interactive", "batch")))
        lo = [eng.submit(p, max_new_tokens=12, latency_class="batch")
              for p in prompts[:2]]
        # Let the batch rows occupy both slots and decode a few tokens
        # before the interactive request arrives — submitted up front it
        # would simply be admitted first (priority queues order the
        # backlog) and nothing would need evicting.
        out = eng.run(max_steps=4)
        hi = eng.submit(prompts[2], max_new_tokens=6,
                        latency_class="interactive")
        out.update(eng.run())
        assert eng.scheduler_stats()["preempt_count"] >= 1
        assert out[hi] == _solo(model, params, prompts[2], 6)
        for uid, p in zip(lo, prompts[:2]):
            assert out[uid] == _solo(model, params, p, 12), uid

    def test_unknown_latency_class_raises(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        with pytest.raises(ValueError, match="unknown latency class"):
            eng.submit(np.array([3, 4, 5]), max_new_tokens=2,
                       latency_class="gold")


# -------------------------------------------------- stall (preempt off)


class TestStall:
    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_starved_row_stalls_and_resumes(self, tiny_lm, depth):
        """preempt=False + asymmetric budgets: the long row runs out of
        blocks mid-decode, freezes on device, and resumes when the short
        rows retire — streams still match solo at every depth."""
        model, params = tiny_lm
        rng = np.random.default_rng(12)
        prompts = [rng.integers(2, 200, size=6) for _ in range(3)]
        # All three rows cross their first block boundary on the same
        # step; the pool (6) covers the two mid-budget rows' growth but
        # not the long row's, and the mid rows live long enough that the
        # long row must actually wait for their blocks.
        budgets = [10, 10, 20]
        eng = ServingEngine(
            model, params, max_batch=3, max_len=64, paged=True,
            block_size=8, num_blocks=6, pipeline_depth=depth,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=False))
        uids = [eng.submit(p, max_new_tokens=m)
                for p, m in zip(prompts, budgets)]
        out = eng.run()
        stats = eng.scheduler_stats()
        assert stats["preempt_count"] == 0
        assert stats["stalls"] > 0
        for uid, p, m in zip(uids, prompts, budgets):
            assert out[uid] == _solo(model, params, p, m), uid

    def test_symmetric_deadlock_raises(self, tiny_lm):
        """Every live row starved at once with nothing left to retire is
        a genuine deadlock: the engine must raise, not spin."""
        model, params = tiny_lm
        rng = np.random.default_rng(13)
        # Each request individually fits the pool (4 blocks worst case,
        # so submit's fail-fast passes) but jointly they want 8: both
        # admit on 2 prompt blocks, grow to 16 tokens, and then stall
        # simultaneously with nothing left to retire.
        eng = ServingEngine(
            model, params, max_batch=2, max_len=64, paged=True,
            block_size=8, num_blocks=4,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=False))
        for _ in range(2):
            eng.submit(rng.integers(2, 200, size=10), max_new_tokens=16)
        with pytest.raises(RuntimeError, match="deadlock"):
            eng.run()


# ----------------------------------------------- occupancy + placement


class TestOccupancy:
    def test_on_demand_raises_live_occupancy_under_overcommit(self, tiny_lm):
        """Same pool, same workload: on-demand admission runs strictly
        more live rows at strictly higher live/reserved occupancy than
        worst-case admission (the bench's overcommit claim)."""
        model, params = tiny_lm
        prompts = _prompts(14, 8, lo=4, hi=8)
        budgets = [16, 5] * 4

        def run(admission, preempt):
            eng = ServingEngine(
                model, params, max_batch=4, max_len=64, paged=True,
                block_size=8, num_blocks=8,
                sched_config=SchedulerConfig(admission=admission,
                                             preempt=preempt))
            for p, m in zip(prompts, budgets):
                eng.submit(p, max_new_tokens=m)
            eng.run()
            return eng.scheduler_stats()

        wc = run("worst_case", False)
        od = run("on_demand", True)
        assert od["mean_live_rows"] > wc["mean_live_rows"]
        assert od["occupancy_live_frac"] > wc["occupancy_live_frac"]

    def test_dp_placement_prefers_emptiest_shard(self):
        """slot_order ranks free slots by their shard's free-block
        headroom; ties fall back to freed-order (the 1-shard identity)."""

        class _KV:
            def __init__(self, alloc, per):
                self.alloc = alloc
                self._per = per

            def slot_shard(self, slot):
                return slot // self._per

        from repro.serving.kvcache.allocator import BlockAllocator

        alloc = BlockAllocator(8, num_shards=2)
        alloc.alloc("r0", 3, shard=0)       # shard 0: 1 free, shard 1: 4
        s = Scheduler()
        kv = _KV(alloc, per=2)
        order = s.slot_order([0, 1, 2, 3], kv, freed_at=[0, 1, 2, 3])
        assert order == [2, 3, 0, 1]        # shard 1's slots first
        # single shard: pure freed-order
        kv1 = _KV(BlockAllocator(8), per=4)
        assert s.slot_order([2, 0, 1], kv1, freed_at=[5, 1, 3]) == [1, 2, 0]


# ------------------------------------------------------- observability


class TestSchedulerObservability:
    def test_events_metrics_and_blocked_set(self, tiny_lm):
        from repro.obs import Telemetry

        model, params = tiny_lm
        tel = Telemetry()
        prompts = _prompts(15, 6, lo=4, hi=10)
        eng = ServingEngine(
            model, params, max_batch=3, max_len=64, paged=True,
            block_size=8, num_blocks=8, telemetry=tel,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=True))
        uids = [eng.submit(p, max_new_tokens=16) for p in prompts]
        eng.run()
        stats = eng.scheduler_stats()
        assert stats["preempt_count"] > 0

        # the admit-blocked observability set must drain as requests
        # retire — an unbounded set would leak over a long-lived engine
        assert eng._obs_blocked == set()

        names = {e.name for e in tel.tracer.events() if e.cat == "sched"}
        assert {"grow", "preempt", "resume"} <= names
        snap = tel.metrics.snapshot()
        pre = snap["serving_preempt_total"]
        assert sum(s["value"] for s in pre["series"]) == \
            stats["preempt_count"]
        assert all("reason" in s["labels"] for s in pre["series"])
        gauge = snap["serving_pool_reserved_vs_live_frac"]
        assert any(0 < s["value"] <= 1 for s in gauge["series"])

    def test_swap_bytes_counter(self, tiny_lm):
        from repro.obs import Telemetry

        model, params = tiny_lm
        tel = Telemetry()
        prompts = _prompts(16, 5, lo=4, hi=10)
        eng = ServingEngine(
            model, params, max_batch=3, max_len=64, paged=True,
            block_size=8, num_blocks=8, telemetry=tel,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=True, resume="swap"))
        for p in prompts:
            eng.submit(p, max_new_tokens=16)
        eng.run()
        stats = eng.scheduler_stats()
        assert stats["swap_bytes"] > 0
        snap = tel.metrics.snapshot()
        got = sum(s["value"]
                  for s in snap["serving_swap_bytes_total"]["series"])
        assert got == stats["swap_bytes"]

    def test_sched_events_in_chrome_export(self, tiny_lm, tmp_path):
        from repro.obs import Telemetry

        model, params = tiny_lm
        tel = Telemetry()
        eng = ServingEngine(
            model, params, max_batch=2, max_len=64, paged=True,
            block_size=8, num_blocks=6, telemetry=tel,
            sched_config=SchedulerConfig(admission="on_demand",
                                         preempt=True))
        for p in _prompts(17, 4, lo=4, hi=8):
            eng.submit(p, max_new_tokens=14)
        eng.run()
        path = tmp_path / "trace.json"
        tel.tracer.export_chrome(str(path))
        doc = json.loads(path.read_text())
        cats = {e.get("cat") for e in doc["traceEvents"]}
        assert "sched" in cats

    def test_scheduler_stats_keys_without_pressure(self, tiny_lm):
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        eng.submit(np.array([3, 4, 5, 6]), max_new_tokens=3)
        eng.run()
        stats = eng.scheduler_stats()
        for key in ("admission_policy", "preempt_enabled", "resume_mode",
                    "priority_classes", "preempt_count", "swap_bytes",
                    "grown_blocks", "resumes", "stalls",
                    "occupancy_live_frac", "mean_live_rows", "queued"):
            assert key in stats, key
        assert stats["preempt_count"] == 0 and stats["queued"] == 0


# --------------------------------------------- interleaving checker ops


class TestInterleaveSchedulerOps:
    def test_clean_with_scheduler_ops(self):
        from repro.analysis.interleave import check_interleavings

        report = check_interleavings()
        assert report.ok, report.violations[:3]

    @pytest.mark.parametrize("bug", ["double_grow", "preempt_in_flight",
                                     "cancel_double_free"])
    def test_seeded_scheduler_bugs_caught(self, bug):
        from repro.analysis.interleave import check_interleavings

        report = check_interleavings(bug=bug, max_ops=6)
        assert not report.ok
        blob = " ".join(report.violations)
        marker = {"double_grow": "ledger",
                  "preempt_in_flight": "in-flight",
                  "cancel_double_free": "double free"}[bug]
        assert marker in blob


# ------------------------------------------------- bench schema 8


class TestBenchSchema8:
    def test_migrate_stamps_scheduler_fields(self):
        from benchmarks.serving_throughput import BENCH_SCHEMA, _migrate_entry

        assert BENCH_SCHEMA == 8
        old = {"rows": [{"label": "dense", "tok_per_s": 10.0}]}
        new = _migrate_entry(old)
        row = new["rows"][0]
        assert row["admission_policy"] == "worst_case"
        assert row["occupancy_live_frac"] is None
        assert row["preempt_count"] == 0
        assert row["mean_live_rows"] is None
        assert row["tok_per_s"] == 10.0   # payload untouched
        assert new["faults"] is None      # pre-fault-tolerance entry

    def test_fresh_rows_keep_their_stamp(self):
        from benchmarks.serving_throughput import _migrate_entry

        entry = {"mesh": {"dp": 1, "tp": 1, "devices": 1}, "audit": None,
                 "telemetry": None, "roofline": None,
                 "rows": [{"label": "x", "admission_policy": "on_demand",
                           "occupancy_live_frac": 0.7, "preempt_count": 3,
                           "mean_live_rows": 5.0}]}
        row = _migrate_entry(entry)["rows"][0]
        assert row["admission_policy"] == "on_demand"
        assert row["occupancy_live_frac"] == 0.7
        assert row["preempt_count"] == 3

    def test_committed_history_is_schema8(self):
        import os

        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
        doc = json.load(open(path))
        assert doc["schema"] == 8
        assert all("faults" in e for e in doc["history"])
        newest = doc["history"][-1]
        oc = newest["summary"]["overcommit"]
        assert oc["occupancy_live_frac_on_demand"] > \
            oc["occupancy_live_frac_worst_case"]
        for row in newest["rows"]:
            assert "admission_policy" in row
            assert "preempt_count" in row
