"""Theorem-level validation of the paper's math (exactness tests).

These are the strongest form of reproduction available without the original
checkpoints: the paper's Theorems 1-4 make *exact* numerical claims which we
verify to float64 tolerance on random and adversarial inputs.
"""

import numpy as np
import pytest

from repro.core import (
    activation_loss,
    asvd_compress,
    compress,
    gram_loss,
    nested_compress,
    split_rank,
    truncated_svd,
)
from repro.core.whitening import make_cholesky_whitener, make_eigen_whitener, make_gamma_whitener

RNG = np.random.default_rng(0)


def _random_problem(m=48, n=32, p=96, seed=0, ill_conditioned=False):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n))
    x = rng.standard_normal((n, p))
    if ill_conditioned:
        # Heavy-tailed activations with a few outlier channels (the paper's
        # motivating regime).
        scales = np.ones(n)
        scales[: max(1, n // 8)] = 50.0
        x = x * scales[:, None]
    return a, x


class TestEckartYoung:
    def test_truncation_error_equals_tail_singular_values(self):
        a, _ = _random_problem(seed=1)
        full = np.linalg.svd(a, compute_uv=False)
        for k in (1, 5, 17):
            ak = truncated_svd(a, k).matrix()
            err = np.linalg.norm(a - ak, "fro")
            expected = np.sqrt(np.sum(full[k:] ** 2))
            np.testing.assert_allclose(err, expected, rtol=1e-10)

    def test_truncated_is_optimal_vs_random_rank_k(self):
        a, _ = _random_problem(seed=2)
        k = 6
        best = np.linalg.norm(a - truncated_svd(a, k).matrix(), "fro")
        rng = np.random.default_rng(3)
        for _ in range(5):
            w = rng.standard_normal((a.shape[0], k))
            z = rng.standard_normal((k, a.shape[1]))
            assert np.linalg.norm(a - w @ z, "fro") >= best - 1e-9


class TestTheorem2Cholesky:
    """ASVD-I: truncation loss of AS equals the truncated singular values."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("ill", [False, True])
    def test_single_direction_loss_is_sigma(self, seed, ill):
        a, x = _random_problem(seed=seed, ill_conditioned=ill)
        gram = x @ x.T
        whit = make_cholesky_whitener(gram, damp=0.0)
        assert whit.method == "asvd1"
        aw = whit.apply_right(a)
        u, s, vt = np.linalg.svd(aw, full_matrices=False)
        for j in (0, 3, len(s) - 1):
            # Drop ONLY direction j.
            keep = np.ones(len(s), bool)
            keep[j] = False
            approx_w = (u[:, keep] * s[keep]) @ vt[keep]
            approx = whit.unapply_right(approx_w)
            loss = activation_loss(a, approx, x)
            np.testing.assert_allclose(loss, s[j], rtol=1e-8)

    @pytest.mark.parametrize("k", [1, 8, 24])
    def test_tail_truncation_loss_is_sqrt_sum_sigma_sq(self, k):
        a, x = _random_problem(seed=4)
        gram = x @ x.T
        whit = make_cholesky_whitener(gram, damp=0.0)
        factors, res = asvd_compress(a, k, whit, use_randomized=False)
        s_all = np.linalg.svd(whit.apply_right(a), compute_uv=False)
        loss = activation_loss(a, factors.matrix(), x)
        expected = np.sqrt(np.sum(s_all[k:] ** 2))
        np.testing.assert_allclose(loss, expected, rtol=1e-8)

    def test_gram_loss_equals_activation_loss(self):
        a, x = _random_problem(seed=5)
        approx = truncated_svd(a, 4).matrix()
        np.testing.assert_allclose(
            gram_loss(a, approx, x @ x.T), activation_loss(a, approx, x), rtol=1e-10
        )


class TestTheorem3Eigen:
    """ASVD-II: same guarantees via eigendecomposition + equivalence w/ ASVD-I."""

    @pytest.mark.parametrize("k", [2, 10])
    def test_tail_truncation_loss(self, k):
        a, x = _random_problem(seed=6)
        gram = x @ x.T
        whit = make_eigen_whitener(gram)
        factors, _ = asvd_compress(a, k, whit, use_randomized=False)
        s_all = np.linalg.svd(whit.apply_right(a), compute_uv=False)
        loss = activation_loss(a, factors.matrix(), x)
        np.testing.assert_allclose(loss, np.sqrt(np.sum(s_all[k:] ** 2)), rtol=1e-8)

    @pytest.mark.parametrize("seed", [0, 7])
    def test_asvd1_equiv_asvd2(self, seed):
        """Paper Thm 3(ii): Cholesky and SVD whitening give the same
        approximation (up to numerics) — their Table 1 shows matching PPL."""
        a, x = _random_problem(seed=seed)
        gram = x @ x.T
        k = 12
        f1 = compress(a, k, "asvd1", gram=gram, damp=0.0, use_randomized=False)
        f2 = compress(a, k, "asvd2", gram=gram, damp=0.0, use_randomized=False)
        np.testing.assert_allclose(f1.matrix(), f2.matrix(), atol=1e-7)

    def test_rank_deficient_gram_pseudo_inverse(self):
        """ASVD-II's selling point: zero eigenvalues handled via pinv."""
        rng = np.random.default_rng(8)
        n, p = 32, 16  # p < n => XX^T rank-deficient
        a = rng.standard_normal((24, n))
        x = rng.standard_normal((n, p))
        gram = x @ x.T
        whit = make_eigen_whitener(gram)
        assert whit.rank <= p
        factors, _ = asvd_compress(a, 8, whit, use_randomized=False)
        assert np.isfinite(factors.matrix()).all()
        # Loss must still be exact on the observable subspace.
        s_all = np.linalg.svd(whit.apply_right(a), compute_uv=False)
        loss = activation_loss(a, factors.matrix(), x)
        np.testing.assert_allclose(loss, np.sqrt(np.sum(s_all[8:] ** 2)), rtol=1e-6)


class TestTheorem4Gamma:
    def test_loss_bounded_by_sigma(self):
        """ASVD-III: loss of dropping direction j is sigma_j * tr(Lam/g^2 v v^T)
        <= sigma_j (gamma = max eigenvalue^0.5)."""
        a, x = _random_problem(seed=9)
        gram = x @ x.T
        whit = make_gamma_whitener(gram)
        aw = whit.apply_right(a)
        u, s, vt = np.linalg.svd(aw, full_matrices=False)
        lam = np.linalg.eigvalsh(gram)[::-1]
        gamma2 = lam[0]
        for j in (0, 5):
            keep = np.ones(len(s), bool)
            keep[j] = False
            approx = whit.unapply_right((u[:, keep] * s[keep]) @ vt[keep])
            loss = activation_loss(a, approx, x)
            # Exact claim from Thm 4(a):
            p = np.linalg.eigh(0.5 * (gram + gram.T))[1][:, ::-1]
            v_j = vt[j]
            expected = s[j] * np.sqrt(v_j @ (np.diag(lam) / gamma2) @ v_j)
            np.testing.assert_allclose(loss, expected, rtol=1e-6)
            assert loss <= s[j] + 1e-9


class TestNested:
    def test_split_rank(self):
        assert split_rank(100, 0.95) == (95, 5)
        assert split_rank(100, 0.80) == (80, 20)
        assert split_rank(1, 0.5) == (1, 0)
        assert split_rank(0, 0.9) == (0, 0)
        k1, k2 = split_rank(7, 0.95)
        assert k1 + k2 == 7 and k1 >= 1

    @pytest.mark.parametrize("variant", ["nsvd1", "nsvd2", "nid1", "nid2"])
    def test_storage_matches_asvd(self, variant):
        """Paper Eq. 6: nested storage/flops == single rank-k factorization."""
        a, x = _random_problem(seed=10)
        gram = x @ x.T
        k = 16
        nested = nested_compress(a, k, variant, gram=gram, k1_frac=0.75,
                                 use_randomized=False)
        single = compress(a, k, "asvd1", gram=gram, use_randomized=False)
        assert nested.param_count() == single.param_count()
        assert nested.rank == single.rank == k

    def test_nested_residual_step_reduces_weight_error(self):
        """Step (5b) adheres to A: weight-space error strictly improves over
        pure ASVD at the same total rank (the paper's robustness mechanism)."""
        a, x = _random_problem(m=64, n=48, p=128, seed=11, ill_conditioned=True)
        gram = x @ x.T
        k = 12
        asvd = compress(a, k, "asvd1", gram=gram, use_randomized=False)
        nsvd = nested_compress(a, k, "nsvd1", gram=gram, k1_frac=0.75,
                               use_randomized=False)
        err_asvd = np.linalg.norm(a - asvd.matrix(), "fro")
        err_nsvd = np.linalg.norm(a - nsvd.matrix(), "fro")
        assert err_nsvd < err_asvd

    def test_nested_ood_robustness(self):
        """Core paper claim in matrix form: calibrate on X1, evaluate the
        activation loss on X2 with a different channel distribution — NSVD
        should beat ASVD (Table 1 CMRC/JP columns analogue)."""
        rng = np.random.default_rng(12)
        m, n, p = 64, 48, 256
        a = rng.standard_normal((m, n))
        scale1 = np.ones(n); scale1[: n // 6] = 30.0     # calibration outliers
        scale2 = np.ones(n); scale2[-n // 6 :] = 30.0    # *different* outliers
        x1 = rng.standard_normal((n, p)) * scale1[:, None]
        x2 = rng.standard_normal((n, p)) * scale2[:, None]
        gram = x1 @ x1.T
        k = 10
        asvd = compress(a, k, "asvd1", gram=gram, use_randomized=False)
        nsvd = nested_compress(a, k, "nsvd1", gram=gram, k1_frac=0.8,
                               use_randomized=False)
        ood_asvd = activation_loss(a, asvd.matrix(), x2)
        ood_nsvd = activation_loss(a, nsvd.matrix(), x2)
        assert ood_nsvd < ood_asvd

    def test_k1_frac_1_degenerates_to_asvd(self):
        a, x = _random_problem(seed=13)
        gram = x @ x.T
        nested = nested_compress(a, 8, "nsvd1", gram=gram, k1_frac=1.0,
                                 use_randomized=False)
        single = compress(a, 8, "asvd1", gram=gram, use_randomized=False)
        np.testing.assert_allclose(nested.matrix(), single.matrix(), atol=1e-8)


class TestNID:
    def test_id_reconstructs_exactly_at_full_rank(self):
        rng = np.random.default_rng(14)
        a = rng.standard_normal((20, 12))
        from repro.core import id_compress

        f = id_compress(a, 12)
        np.testing.assert_allclose(f.matrix(), a, atol=1e-8)

    def test_id_columns_are_actual_columns(self):
        rng = np.random.default_rng(15)
        a = rng.standard_normal((20, 12))
        from repro.core import column_id

        cols, t = column_id(a, 5)
        np.testing.assert_allclose(a[:, cols] @ t[:, cols], a[:, cols], atol=1e-8)
        # Interpolation matrix is identity on chosen columns.
        np.testing.assert_allclose(t[:, cols], np.eye(5), atol=1e-10)

    def test_id_error_close_to_svd_bound(self):
        rng = np.random.default_rng(16)
        a = rng.standard_normal((40, 30))
        from repro.core import id_compress

        k = 10
        svd_err = np.linalg.norm(a - truncated_svd(a, k).matrix(), "fro")
        id_err = np.linalg.norm(a - id_compress(a, k).matrix(), "fro")
        # Pivoted-QR ID satisfies a (1 + k(n-k))^(1/2)-factor bound; in
        # practice it's within ~2x for Gaussian matrices.
        assert svd_err <= id_err <= 3.0 * svd_err
