"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, with
shape/dtype sweeps (the kernels target TPU; interpret=True executes the
kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram.ops import gram_accumulate
from repro.kernels.gram.ref import gram_accumulate_ref
from repro.kernels.nested_lowrank.ops import nested_lowrank_matmul
from repro.kernels.nested_lowrank.ref import nested_lowrank_matmul_ref
from repro.kernels.rwkv6.ops import rwkv6_attention
from repro.kernels.rwkv6.ref import rwkv6_scan_ref


def _tol(dtype):
    # bf16: the kernel accumulates in fp32 while the oracle round-trips
    # intermediates through bf16, so small divergence is expected (and the
    # kernel is the MORE accurate side).
    return dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestNestedLowRank:
    @pytest.mark.parametrize("m,kin,k1,k2,n", [
        (8, 64, 16, 4, 128),
        (16, 128, 32, 8, 256),
        (4, 96, 24, 8, 192),     # non-128-aligned K
        (32, 256, 128, 16, 512), # multiple output tiles
        (8, 64, 16, 4, 100),     # N not divisible by block -> padded
        (8, 64, 16, 4, 320),     # N > block and not divisible -> padded tiles
        (4, 64, 16, 4, 130),     # N barely over one block
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, m, kin, k1, k2, n, dtype):
        rng = np.random.default_rng(0)
        mk = lambda *s: jnp.asarray(rng.standard_normal(s) * 0.3, dtype)
        x, u, v = mk(m, kin), mk(kin, k1), mk(k1, n)
        u2, v2 = mk(kin, k2), mk(k2, n)
        got = nested_lowrank_matmul(x, u, v, u2, v2, block_n=128, interpret=True)
        want = nested_lowrank_matmul_ref(x, u, v, u2, v2)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
        )

    def test_linear_apply_routes_nested_through_ops(self):
        """linear_apply's default dispatch (ops.py: kernel on TPU, oracle on
        CPU) must agree with the explicit jnp path for nested params."""
        from repro.core.lowrank import linear_apply

        rng = np.random.default_rng(7)
        params = {
            "u": jnp.asarray(rng.standard_normal((64, 16)), jnp.float32),
            "v": jnp.asarray(rng.standard_normal((16, 96)), jnp.float32),
            "u2": jnp.asarray(rng.standard_normal((64, 4)), jnp.float32),
            "v2": jnp.asarray(rng.standard_normal((4, 96)), jnp.float32),
        }
        x = jnp.asarray(rng.standard_normal((3, 5, 64)), jnp.float32)
        auto = linear_apply(params, x)  # default: route through ops
        plain = linear_apply(params, x, use_kernel=False)
        np.testing.assert_allclose(np.asarray(auto), np.asarray(plain),
                                   rtol=1e-6, atol=1e-6)

    def test_batched_leading_dims(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 3, 64)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
        u2 = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
        v2 = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
        got = nested_lowrank_matmul(x, u, v, u2, v2, interpret=True)
        want = nested_lowrank_matmul_ref(x, u, v, u2, v2)
        assert got.shape == (2, 3, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


class TestGram:
    @pytest.mark.parametrize("rows,n", [
        (512, 128), (1024, 256), (300, 96), (64, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, rows, n, dtype):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((rows, n)) * 0.5, dtype)
        got = gram_accumulate(x, block_n=64, block_t=128, interpret=True)
        want = gram_accumulate_ref(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=3e-1 if dtype == jnp.bfloat16 else 1e-3,
        )

    def test_gram_is_symmetric_psd(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
        g = np.asarray(gram_accumulate(x, block_n=64, block_t=64, interpret=True))
        np.testing.assert_allclose(g, g.T, rtol=1e-6)
        evals = np.linalg.eigvalsh(g)
        assert evals.min() > -1e-3


class TestRWKV6:
    @pytest.mark.parametrize("bh,t,k,chunk", [
        (2, 32, 16, 8),
        (4, 64, 32, 16),
        (1, 48, 64, 16),
        (2, 40, 16, 16),   # T not divisible by chunk -> padded
    ])
    def test_matches_scan_oracle(self, bh, t, k, chunk):
        rng = np.random.default_rng(4)
        r = jnp.asarray(rng.standard_normal((bh, t, k)) * 0.5, jnp.float32)
        kk = jnp.asarray(rng.standard_normal((bh, t, k)) * 0.5, jnp.float32)
        v = jnp.asarray(rng.standard_normal((bh, t, k)) * 0.5, jnp.float32)
        # decays in (0, 1) incl. strong decay (the overflow-prone regime)
        w = jnp.asarray(rng.uniform(0.01, 0.999, (bh, t, k)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((bh, k)) * 0.5, jnp.float32)
        got = rwkv6_attention(r, kk, v, w, u, chunk=chunk, interpret=True)
        want = rwkv6_scan_ref(r, kk, v, w, u)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
        )

    def test_extreme_decay_no_overflow(self):
        """Strong decay (w -> 0) is where naive chunk algebra overflows."""
        rng = np.random.default_rng(5)
        bh, t, k = 2, 32, 16
        r = jnp.asarray(rng.standard_normal((bh, t, k)), jnp.float32)
        kk = jnp.asarray(rng.standard_normal((bh, t, k)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((bh, t, k)), jnp.float32)
        w = jnp.full((bh, t, k), 1e-6, jnp.float32)
        u = jnp.zeros((bh, k), jnp.float32)
        got = rwkv6_attention(r, kk, v, w, u, chunk=8, interpret=True)
        want = rwkv6_scan_ref(r, kk, v, w, u)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)

    def test_model_layer_uses_same_math(self):
        """The rwkv6 model layer's scan and the kernel oracle agree on a
        round-trip through the model's tensor layout."""
        from repro.configs import get_config
        from repro.models import build_model

        cfg = get_config("rwkv6-1.6b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
        logits, _, _ = model.apply(params, tokens, mode="train")
        assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("rows", [64, 192])
def test_gram_kernel_vs_calibration_update(rows):
    """Kernel output feeds the same Gram the calibration runner computes."""
    from repro.calib.gram import gram_update

    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((rows, 32)), jnp.float32)
    g_kernel = gram_accumulate(x, block_n=32, block_t=64, interpret=True)
    g_runner, _, _ = gram_update(x)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_runner), rtol=1e-4, atol=1e-4)
