"""Regression tests for rank budgeting under multiple_of alignment and for
the GramStore gram/absmean fallback pairing."""

import numpy as np
import pytest

from repro.core.compress import GramStore
from repro.core.ratio import (
    MatrixSpec,
    importance_ranks,
    rank_for_ratio,
)


class TestRankForRatioAlignment:
    @pytest.mark.parametrize("m,n", [
        (256, 256), (512, 2048), (4096, 4096), (4096, 11008), (768, 3072),
        (300, 500),
    ])
    @pytest.mark.parametrize("ratio", [0.1, 0.3, 0.5, 0.7, 0.9])
    def test_never_exceeds_budget_unless_minimum(self, m, n, ratio):
        mult = 128
        k = rank_for_ratio(m, n, ratio, multiple_of=mult)
        budget = (1.0 - ratio) * m * n
        storage = (m + n) * k
        if storage > budget:
            # Only allowed when even one multiple_of is already over budget,
            # in which case the documented minimum is returned.
            assert k == min(mult, max(1, (m * n) // (m + n)))
            assert (m + n) * mult > budget or mult > (m * n) // (m + n)
        assert k >= 1

    def test_small_rank_rounds_down_not_up(self):
        # Unaligned rank is 204; the old code clamped ranks below 128 UP to
        # 128.  With m=n=256 and ratio=0.9 the budget allows only rank 12,
        # so alignment must fall back to the documented minimum of one
        # multiple_of -- while ratio=0.5 (rank 64 unaligned... ) stays <= budget.
        m = n = 1024
        k = rank_for_ratio(m, n, 0.9, multiple_of=128)
        # floor(0.1 * 1024 * 1024 / 2048) = 51 -> rounds DOWN to 0 -> minimum 128
        assert k == 128
        k2 = rank_for_ratio(m, n, 0.5, multiple_of=128)
        # floor(0.5 * 1024 * 1024 / 2048) = 256 -> stays 256, within budget
        assert k2 == 256
        assert (m + n) * k2 <= 0.5 * m * n

    def test_round_down_when_rounding_up_would_overshoot(self):
        m, n = 4096, 4096
        ratio = 0.8
        k = rank_for_ratio(m, n, ratio, multiple_of=128)
        # Unaligned rank = floor(0.2*4096*4096/8192) = 409; old code kept
        # max(128, 384) = 384 (fine), but e.g. ratio=0.95 gives 102 -> the
        # old code returned 128 (over budget); now it must return 128 only
        # as the minimum case and flag nothing else.
        assert (m + n) * k <= (1 - ratio) * m * n
        k95 = rank_for_ratio(m, n, 0.95, multiple_of=128)
        assert k95 == 128  # documented minimum (floor would be rank 0)

    def test_importance_ranks_alignment_respects_budget(self):
        rng = np.random.default_rng(0)
        specs = [
            MatrixSpec("a", 512, 512, "g"),
            MatrixSpec("b", 1024, 256, "g"),
            MatrixSpec("c", 2048, 2048, "g"),
        ]
        tails = {
            s.name: np.sort(rng.uniform(0.1, 5.0, size=min(s.m, s.n)))[::-1]
            for s in specs
        }
        ratio = 0.6
        unaligned = importance_ranks(specs, ratio, tails)
        aligned = importance_ranks(specs, ratio, tails, multiple_of=128)
        for s in specs:
            k = aligned[s.name]
            assert k == 128 or k % 128 == 0
            # Alignment never rounds a rank UP past the unaligned allocation
            # unless the floor would be zero (documented minimum).
            if unaligned[s.name] >= 128:
                assert k <= unaligned[s.name]
            else:
                assert k == min(128, max(1, (s.m * s.n) // (s.m + s.n)))


class TestGramAbsmeanPairing:
    def _store(self):
        store = GramStore()
        n = 8
        rng = np.random.default_rng(1)
        layer_g = np.eye(n) * 4.0
        layer_a = np.full((n,), 2.0)
        store.update("layer", layer_g, layer_a * 1000, 1000.0)
        expert_g = rng.standard_normal((n, n))
        expert_g = expert_g @ expert_g.T
        store.update("layer/0", expert_g, np.full((n,), 7.0) * 3, 3.0)
        return store

    def test_absmean_falls_back_with_gram(self):
        """When gram() falls back to the layer Gram (too few tokens), the
        absmean must come from the SAME fallback statistics."""
        store = self._store()
        min_count = 10  # expert saw 3 tokens -> both must fall back
        g = store.gram("layer/0", fallback="layer", min_count=min_count)
        a = store.absmean("layer/0", fallback="layer", min_count=min_count)
        np.testing.assert_allclose(g, store.gram("layer"))
        np.testing.assert_allclose(a, store.absmean("layer"))

    def test_absmean_uses_own_stats_when_count_sufficient(self):
        store = self._store()
        a = store.absmean("layer/0", fallback="layer", min_count=2)
        np.testing.assert_allclose(a, np.full((8,), 7.0))

    def test_absmean_missing_raises(self):
        store = self._store()
        with pytest.raises(KeyError):
            store.absmean("nope", fallback="also-nope")
