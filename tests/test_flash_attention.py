"""Flash-attention Pallas kernel vs naive oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("b,s,hq,hkv,hd,bq,bk", [
    (1, 64, 4, 4, 16, 16, 16),    # MHA
    (2, 64, 8, 2, 16, 16, 16),    # GQA g=4
    (1, 128, 4, 1, 32, 32, 32),   # MQA
    (1, 96, 4, 2, 16, 32, 32),    # S not divisible by block -> padded
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(b, s, hq, hkv, hd, bq, bk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)) * 0.5, dtype)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = flash_attention_ref(q, k, v)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_matches_model_chunked_attention():
    """The kernel and the model's jnp chunked path agree (same math twice)."""
    from repro.models.attention import chunked_causal_attention

    rng = np.random.default_rng(1)
    b, s, hq, hkv, hd = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    kern = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    jnp_chunked = chunked_causal_attention(q, k, v, scale=1.0 / hd ** 0.5, chunk=16)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(jnp_chunked),
                               rtol=2e-4, atol=2e-4)
