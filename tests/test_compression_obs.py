"""Compression-observability tests: telemetry is a pure observer.

Load-bearing invariants: (1) compressed params are BIT-IDENTICAL with
``CompressionTelemetry`` attached vs absent — diagnostics are computed
from the finished factors, never fed back; (2) every planned TargetSpec
yields a ``DecompositionReport`` with the full field set, exported both
as the plan-level JSON artifact and as Prometheus families; (3) the
diagnostics are honest — whitening can only help in activation space
(outlier absorption >= -eps vs a rank-matched plain SVD), tail mass is
the squared whitened error; (4) calibration telemetry sees a constructed
outlier channel and the min_count/missing Gram fallbacks; (5) the
GramStore schema stamp round-trips, legacy unstamped files load, and
unknown-schema/corrupt files are rejected instead of misread."""

import json
import math

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    GramStore,
    build_plan,
    compress_params,
)
from repro.core.compress import GRAM_STORE_SCHEMA
from repro.core.nsvd import decomposition_diagnostics, nested_compress
from repro.core.plan import TargetSpec
from repro.obs import NULL_COMPRESSION_TELEMETRY, CompressionTelemetry
from repro.obs.compression import gram_activation_stats

N_IN, N_OUT, LAYERS = 24, 16, 3


def _tree_leaves(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_leaves(v, prefix + (k,))
    else:
        yield prefix, np.asarray(tree)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    params = {
        "blk": {
            "wi": {"kernel": rng.standard_normal(
                (LAYERS, N_IN, N_OUT)).astype(np.float32)},
            "wo": {"kernel": rng.standard_normal(
                (N_IN, N_OUT)).astype(np.float32)},
        }
    }
    targets = [
        TargetSpec(path=("blk", "wi"), in_dim=N_IN, out_dim=N_OUT,
                   gram_key="g/in", stacked=(LAYERS,)),
        TargetSpec(path=("blk", "wo"), in_dim=N_IN, out_dim=N_OUT,
                   gram_key="g/out"),
    ]
    store = GramStore()
    for key in ("g/in", "g/in/0", "g/in/1", "g/out"):
        x = rng.standard_normal((200, N_IN))
        x[:, 3] *= 10.0  # one hot outlier channel
        store.update(key, x.T @ x, np.abs(x).sum(0), 200.0)
    # "g/in/2" exists but starved below min_count (= N_IN // 4 = 6 rows):
    # the stacked pass must fall back to the shared key for that slice.
    x = rng.standard_normal((2, N_IN))
    store.update("g/in/2", x.T @ x, np.abs(x).sum(0), 2.0)
    cfg = CompressionConfig(method="nsvd1", ratio=0.3, dtype="float32",
                            use_randomized=False)
    plan = build_plan(targets, cfg)
    return params, plan, store


def test_params_bit_identical_with_telemetry(setup):
    params, plan, store = setup
    tel = CompressionTelemetry()
    with_tel = compress_params(params, plan, store, telemetry=tel)
    without = compress_params(params, plan, store)
    null = compress_params(params, plan, store,
                           telemetry=NULL_COMPRESSION_TELEMETRY)
    a = dict(_tree_leaves(with_tel))
    b = dict(_tree_leaves(without))
    c = dict(_tree_leaves(null))
    assert a.keys() == b.keys() == c.keys()
    for k in a:
        assert (a[k] == b[k]).all(), k
        assert (a[k] == c[k]).all(), k


def test_report_per_target_and_fields(setup):
    params, plan, store = setup
    tel = CompressionTelemetry()
    compress_params(params, plan, store, telemetry=tel)
    assert set(tel.reports) == {t.name for t in plan.targets}
    for name, r in tel.reports.items():
        assert r.rank == plan.ranks[name]
        assert r.k1 + r.k2 == r.rank
        assert r.k1 >= 1
        assert 0.0 <= r.plain_rel_err <= 1.5
        assert 0.0 <= r.whitened_rel_err <= 1.5
        # per slice the tail mass IS the squared whitened error; the
        # target aggregate averages each separately, so mean-of-squares
        # >= square-of-mean (Jensen) is the invariant that survives
        for s in r.slices:
            assert s["sv_tail_mass"] == pytest.approx(
                s["whitened_rel_err"] ** 2, rel=1e-9)
        assert r.sv_tail_mass >= r.whitened_rel_err ** 2 - 1e-12
        assert r.dense_params > r.factored_params > 0
        assert r.achieved_ratio == pytest.approx(
            1.0 - r.factored_params / r.dense_params)
        assert r.seconds >= 0.0
    # Stacked target: one slice record per layer, starved slice counted.
    wi = tel.reports["blk/wi"]
    assert len(wi.slices) == LAYERS
    assert wi.gram_fallback_slices == 1
    assert tel.reports["blk/wo"].gram_fallback_slices == 0


def test_whitening_beats_plain_svd_in_activation_space(setup):
    """The paper's mechanism: the activation-aware step absorbs the
    outlier channel, so its activation-weighted error never exceeds a
    rank-matched plain SVD's (absorption ratio >= -eps)."""
    params, plan, store = setup
    tel = CompressionTelemetry()
    compress_params(params, plan, store, telemetry=tel)
    for r in tel.reports.values():
        assert not math.isnan(r.outlier_absorption)
        assert r.outlier_absorption >= -1e-9
        for s in r.slices:
            assert s["outlier_absorption"] >= -1e-9


def test_plan_report_artifact_and_prometheus(setup, tmp_path):
    params, plan, store = setup
    tel = CompressionTelemetry()
    tel.on_calib_store(store)
    compress_params(params, plan, store, telemetry=tel)

    path = tmp_path / "report.json"
    doc = tel.write_report(str(path), plan=plan)
    loaded = json.loads(path.read_text())
    assert loaded["schema"] == 1
    assert {t["target"] for t in loaded["targets"]} == \
        {t.name for t in plan.targets}
    tot = loaded["totals"]
    assert tot["targets"] == len(plan.targets)
    assert 0.0 < tot["achieved_ratio"] < 1.0
    assert tot["gram_fallback_slices"] == 1
    assert loaded["plan"]["ranks"] == dict(plan.ranks)
    assert "g/in" in loaded["calibration"]
    # json round-trip must be strict-parser safe (no NaN/Infinity tokens)
    json.loads(path.read_text(), parse_constant=lambda c: pytest.fail(c))
    assert doc["totals"]["targets"] == tot["targets"]

    text = tel.metrics.prometheus_text()
    for fam in ("compress_plain_rel_err", "compress_whitened_rel_err",
                "compress_sv_tail_mass", "compress_outlier_absorption",
                "compress_rank_achieved", "compress_rank_requested",
                "compress_factored_params", "compress_targets_total",
                "compress_gram_fallbacks_total",
                "compress_calib_outlier_channel_frac",
                "compress_calib_gram_condition_number"):
        assert fam in text, fam
    for t in plan.targets:
        assert f'target="{t.name}"' in text


def test_calibration_outlier_stats(setup):
    _, _, store = setup
    stats = gram_activation_stats(
        store.gram("g/in"), store.absmean("g/in"), store.count("g/in"))
    assert stats["channels"] == N_IN
    assert stats["samples"] == 200.0
    # exactly the one scaled channel crosses 2x and 4x the mean; none 8x
    assert stats["outlier_frac"][2.0] == pytest.approx(1 / N_IN)
    assert stats["outlier_frac"][4.0] == pytest.approx(1 / N_IN)
    assert stats["outlier_frac"][8.0] == 0.0
    assert stats["absmean_max"] > 4 * stats["absmean_mean"]
    assert stats["gram_cond"] > 10.0 and math.isfinite(stats["gram_cond"])
    assert 0.0 < stats["gram_rank_frac"] <= 1.0


def test_calib_hooks_fill_registry(setup):
    _, _, store = setup
    tel = CompressionTelemetry()
    tel.on_calib_batch({"g/in": 320, "g/out": 320})
    tel.on_calib_batch({"g/in": 320, "g/out": 320})
    tel.on_calib_store(store)
    snap = tel.metrics.snapshot()
    assert snap["compress_calib_batches_total"]["series"][0]["value"] == 2
    rows = {tuple(sorted(s["labels"].items())): s["value"]
            for s in snap["compress_calib_rows_total"]["series"]}
    assert rows[(("tap", "g/in"),)] == 640
    assert set(tel.calib) == set(store.keys())


def test_decomposition_diagnostics_consistency():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((N_OUT, N_IN))
    x = rng.standard_normal((500, N_IN))
    x[:, 1] *= 8.0
    gram = x.T @ x
    k = 6
    factors = nested_compress(a, k, "nsvd1", gram=gram, k1_frac=0.9,
                              use_randomized=False)
    d = decomposition_diagnostics(a, factors, gram=gram,
                                  use_randomized=False)
    assert d["rank"] == k
    assert d["k1"] + d["k2"] == k
    # whitened_rel_err matches the direct activation-space computation
    approx = factors.matrix()
    num = np.linalg.norm((a - approx) @ x.T, "fro")
    den = np.linalg.norm(a @ x.T, "fro")
    assert d["whitened_rel_err"] == pytest.approx(num / den, rel=1e-6)
    assert d["sv_tail_mass"] == pytest.approx((num / den) ** 2, rel=1e-6)
    # without a Gram only weight-space numbers exist
    d2 = decomposition_diagnostics(a, factors, gram=None)
    assert math.isnan(d2["whitened_rel_err"])
    assert d2["plain_rel_err"] == pytest.approx(d["plain_rel_err"])
    # compare_plain=False skips the extra SVD
    d3 = decomposition_diagnostics(a, factors, gram=gram,
                                   compare_plain=False)
    assert math.isnan(d3["outlier_absorption"])


# ---------------------------------------------------------------- GramStore


def test_gramstore_schema_roundtrip(setup, tmp_path):
    _, _, store = setup
    path = tmp_path / "grams.npz"
    store.save(str(path))
    data = np.load(path)
    assert int(data["__schema__"]) == GRAM_STORE_SCHEMA
    loaded = GramStore.load(str(path))
    assert set(loaded.keys()) == set(store.keys())
    for k in store.keys():
        np.testing.assert_array_equal(loaded.gram(k), store.gram(k))
        np.testing.assert_array_equal(loaded.absmean(k), store.absmean(k))
        assert loaded.count(k) == store.count(k)
    # fallback decisions survive the round trip
    assert loaded.resolve("g/in/2", fallback="g/in", min_count=6) == \
        ("g/in", "min_count")
    assert loaded.resolve("g/in/9", fallback="g/in") == ("g/in", "missing")
    assert loaded.resolve("g/in/0", fallback="g/in", min_count=6) == \
        ("g/in/0", None)


def test_gramstore_legacy_unstamped_load(setup, tmp_path):
    _, _, store = setup
    path = tmp_path / "legacy.npz"
    np.savez_compressed(  # schema-1 layout: same arrays, no stamp
        path,
        **{f"g::{k}": store.gram(k) for k in store.keys()},
        **{f"a::{k}": store._absmean[k] for k in store.keys()},
        **{f"c::{k}": np.asarray(store.count(k)) for k in store.keys()},
    )
    loaded = GramStore.load(str(path))
    assert set(loaded.keys()) == set(store.keys())
    np.testing.assert_array_equal(loaded.gram("g/in"), store.gram("g/in"))


def test_gramstore_rejects_unknown_schema(tmp_path):
    path = tmp_path / "future.npz"
    np.savez_compressed(path, __schema__=np.asarray(GRAM_STORE_SCHEMA + 1))
    with pytest.raises(ValueError, match="schema"):
        GramStore.load(str(path))


def test_gramstore_rejects_corrupt_file(tmp_path):
    g = np.eye(4)
    path = tmp_path / "missing_count.npz"
    np.savez_compressed(path, **{"g::k": g, "a::k": np.ones(4)})
    with pytest.raises(ValueError, match="corrupt"):
        GramStore.load(str(path))
    path2 = tmp_path / "shape_mismatch.npz"
    np.savez_compressed(path2, **{"g::k": g, "a::k": np.ones(3),
                                  "c::k": np.asarray(1.0)})
    with pytest.raises(ValueError, match="corrupt"):
        GramStore.load(str(path2))


# --------------------------------------------------------------- plan summary


def test_plan_summary_achieved_vs_requested(setup):
    _, plan, _ = setup
    rows = plan.target_rows()
    assert {r["target"] for r in rows} == {t.name for t in plan.targets}
    for r in rows:
        assert r["rank"] >= 1
        assert r["ratio_delta"] == pytest.approx(
            r["achieved_ratio"] - plan.config.ratio)
    text = plan.summary()
    assert "delta" in text
    for t in plan.targets:
        assert t.name in text

    # rank alignment forces achieved != requested; summary surfaces it
    cfg = CompressionConfig(method="nsvd1", ratio=0.3, multiple_of=4,
                            dtype="float32", use_randomized=False)
    aligned = build_plan(plan.targets, cfg)
    arows = aligned.target_rows()
    assert any(r["rank"] != r["requested_rank"] for r in arows)
    assert "requested" in aligned.summary()
