"""Depth-K step-pipeline tests: depth-1 == the serial dispatch->sync loop,
depth>1 produces token-identical streams (greedy, temperature with slot
reuse, speculative) on both cache layouts, drain discipline around the
host-mutating events (admission, defrag, EOS/completion flush), device-side
finish exits (token budget + max_len + EOS all clear `active` on device),
the cached loop-invariant host inputs, and the schema-8 BENCH_serving.json
smoke."""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs.paper_models import small_lm
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.serving.spec import SpecConfig

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = small_lm(name="tiny-pipe", vocab_size=VOCAB, num_layers=2,
                   d_model=64, d_ff=96, num_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def draft_params(tiny_lm):
    """Perturbed weights stand in for a higher-ratio NSVD twin: real
    rejections exercise the verify root's length rollback under depth>1."""
    _, params = tiny_lm
    k = jax.random.key(99)
    return jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(k, x.shape, x.dtype)
        if x.ndim >= 2 else x,
        params,
    )


def _workload(model, params, depth, prompts, lens, temps=None, *,
              max_batch=2, seed=0, **kw):
    """Serve a staggered-finish workload (forces mid-flight admission and
    slot reuse) and return each request's tokens in submit order."""
    eng = ServingEngine(model, params, max_batch=max_batch, max_len=64,
                        seed=seed, pipeline_depth=depth, **kw)
    temps = temps or [0.0] * len(prompts)
    uids = [eng.submit(p, max_new_tokens=m, temperature=t)
            for p, m, t in zip(prompts, lens, temps)]
    out = eng.run()
    return [out[u] for u in uids], eng


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(1)
    return [rng.integers(2, 200, size=n) for n in (6, 18, 7, 5, 9, 4)]


LENS = [9, 3, 6, 4, 7, 5]  # staggered finishes -> slots free mid-flight


# ------------------------------------------------------- depth equivalence


class TestDepthEquivalence:
    @pytest.mark.parametrize("paged", [True, False])
    def test_greedy_streams_identical_across_depths(self, tiny_lm, prompts,
                                                    paged):
        model, params = tiny_lm
        base, _ = _workload(model, params, 1, prompts, LENS, paged=paged)
        for depth in (2, 4):
            got, eng = _workload(model, params, depth, prompts, LENS,
                                 paged=paged)
            assert got == base, f"depth={depth} paged={paged}"
            assert eng.pipeline_depth == depth

    @pytest.mark.parametrize("paged", [True, False])
    def test_temperature_with_slot_reuse_identical(self, tiny_lm, prompts,
                                                   paged):
        """The sharpest depth hazard: a row that finishes at step N has one
        garbage step in flight under depth 2 — if that step advanced the
        slot's PRNG key, the NEXT occupant's sampled stream would diverge
        from depth 1.  Device-side budget exits mask the row in-step, so
        the key chain (and the readmitted request's tokens) must match
        exactly."""
        model, params = tiny_lm
        temps = [0.8] * len(prompts)
        base, _ = _workload(model, params, 1, prompts, LENS, temps,
                            paged=paged, seed=11)
        got, _ = _workload(model, params, 2, prompts, LENS, temps,
                           paged=paged, seed=11)
        assert got == base, f"paged={paged}"

    @pytest.mark.parametrize("paged", [True, False])
    def test_spec_streams_and_accounting_identical(self, tiny_lm, prompts,
                                                   draft_params, paged):
        model, params = tiny_lm
        spec = SpecConfig(draft_params=draft_params, k=3)
        base, b_eng = _workload(model, params, 1, prompts, LENS,
                                paged=paged, spec_config=spec)
        got, g_eng = _workload(model, params, 2, prompts, LENS,
                               paged=paged, spec_config=spec)
        assert got == base, f"paged={paged}"
        bs, gs = b_eng.spec_stats(), g_eng.spec_stats()
        assert (gs["proposed"], gs["accepted"], gs["committed"]) == \
            (bs["proposed"], bs["accepted"], bs["committed"])

    def test_spec_temperature_with_slot_reuse_identical(self, tiny_lm,
                                                        prompts,
                                                        draft_params):
        """Speculative + temperature + slot reuse across depths: both the
        draft proposal keys and the verify accept/resample keys are
        per-REQUEST chains, so accept/reject realizations cannot depend on
        pipeline-induced scheduling shifts."""
        model, params = tiny_lm
        temps = [0.8] * len(prompts)
        spec = SpecConfig(draft_params=draft_params, k=3)
        base, _ = _workload(model, params, 1, prompts, LENS, temps,
                            spec_config=spec, seed=11)
        got, _ = _workload(model, params, 2, prompts, LENS, temps,
                           spec_config=spec, seed=11)
        assert got == base

    def test_streams_independent_of_max_batch_scheduling(self, tiny_lm,
                                                         prompts):
        """Per-request keys make a request's sampled stream a function of
        (seed, uid, prompt) only: the same submissions produce the same
        tokens whether they run solo-batch or contended."""
        model, params = tiny_lm
        temps = [0.7] * len(prompts)
        wide, _ = _workload(model, params, 2, prompts, LENS, temps,
                            max_batch=4, seed=11)
        narrow, _ = _workload(model, params, 2, prompts, LENS, temps,
                              max_batch=2, seed=11)
        assert wide == narrow

    def test_dynamic_k_spec_forces_depth1_ring_and_matches(self, tiny_lm,
                                                           prompts,
                                                           draft_params):
        """Per-row window feedback (k_row for step N+1 needs step N's
        acceptance) cannot run ahead: the ring drains to depth 1 and the
        streams still match plain decoding."""
        model, params = tiny_lm
        spec = SpecConfig(draft_params=draft_params, k=4, dynamic_k=True)
        base, _ = _workload(model, params, 1, prompts, LENS,
                            spec_config=spec)
        got, _ = _workload(model, params, 2, prompts, LENS,
                           spec_config=spec)
        assert got == base


# --------------------------------------------------------- drain semantics


class TestDrainSemantics:
    def test_eos_flush_emits_every_token_exactly_once(self, tiny_lm):
        """EOS mid-stream under depth 2: the finishing step and the garbage
        step behind it are both in flight — the flush must emit the
        committed tokens once each, truncated at (and including) the
        EOS."""
        model, params = tiny_lm
        rng = np.random.default_rng(3)
        p = rng.integers(2, 200, size=7)
        full, _ = _workload(model, params, 1, [p], [8], max_batch=1)
        eos = full[0][2]
        for depth in (1, 2, 3):
            eng = ServingEngine(model, params, max_batch=1, max_len=64,
                                pipeline_depth=depth)
            uid = eng.submit(p, max_new_tokens=8, eos_id=eos)
            out = eng.run()
            assert out[uid] == full[0][:3], f"depth={depth}"
            # Device-side exit fired in the sampling step itself.
            assert not bool(np.asarray(eng._active_dev)[0])

    def test_completion_flush_exact_token_counts(self, tiny_lm, prompts):
        """max_new_tokens finishes under depth>1 must emit exactly
        max_new tokens — the in-flight garbage step's sample for that row
        is discarded, not appended."""
        model, params = tiny_lm
        got, _ = _workload(model, params, 3, prompts, LENS)
        assert [len(g) for g in got] == LENS

    def test_admission_drains_ring(self, tiny_lm, prompts):
        """_admit() must consume every in-flight step before touching
        slots: no ring entry ever straddles a change of slot occupant."""
        model, params = tiny_lm
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            pipeline_depth=2)
        eng.submit(prompts[0], max_new_tokens=6)
        eng._admit()
        eng.step()
        eng.step()
        assert len(eng._ring) > 0
        eng.submit(prompts[1], max_new_tokens=4)
        eng._admit()
        assert len(eng._ring) == 0

    def test_defrag_drains_ring_and_preserves_streams(self, tiny_lm,
                                                      prompts):
        """Mid-flight defrag under depth 2: the pool permutation comes from
        host allocator state, so the ring drains first — and the token
        streams match the depth-1 defrag-free run."""
        model, params = tiny_lm
        base, _ = _workload(model, params, 1, prompts[:4], LENS[:4])

        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            pipeline_depth=2)
        uids = [eng.submit(p, max_new_tokens=m)
                for p, m in zip(prompts[:4], LENS[:4])]
        finished = {}
        for _ in range(200):
            if eng.queue or eng._prefilling:
                for r in eng._admit():
                    finished[r.uid] = r.generated
            if not eng.active.any():
                for r in eng.drain():
                    finished[r.uid] = r.generated
                if not eng.active.any():
                    if not eng.queue and not eng._prefilling:
                        break
                    continue
            for r in eng.step():
                finished[r.uid] = r.generated
            eng.defrag()
            assert len(eng._ring) == 0  # defrag consumed the in-flight step
        assert [finished[u] for u in uids] == base

    def test_drain_returns_finishes_consumed_by_internal_drains(self,
                                                                tiny_lm):
        """A request whose finishing step is consumed by defrag()'s
        internal drain must still surface from the next public call."""
        model, params = tiny_lm
        rng = np.random.default_rng(4)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            pipeline_depth=2)
        uid = eng.submit(rng.integers(2, 200, size=5), max_new_tokens=3)
        eng._admit()  # emits token 1 at admission
        assert eng.step() == []   # dispatch token 2 (ring: 1, no consume)
        assert eng.step() == []   # dispatch token 3, consume token 2
        # The FINISHING step (token 3) is now in flight.
        eng.defrag()  # internal drain consumes the finish
        got = eng.drain()
        assert [r.uid for r in got] == [uid]
        assert len(got[0].generated) == 3


# ------------------------------------------------- device-resident inputs


class TestCachedHostInputs:
    def test_steady_state_reuses_host_input_buffers(self, tiny_lm):
        """temps/eos/host_keep upload once per admission/finish event, not
        once per step: between events dispatch reuses the same device
        arrays."""
        model, params = tiny_lm
        rng = np.random.default_rng(5)
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            pipeline_depth=1)
        eng.submit(rng.integers(2, 200, size=6), max_new_tokens=8)
        eng._admit()
        eng.step()
        keep0, temps0, eos0 = eng._keep_dev, eng._temps_dev, eng._eos_dev
        for _ in range(3):
            eng.step()
        assert eng._keep_dev is keep0
        assert eng._temps_dev is temps0
        assert eng._eos_dev is eos0

    def test_finish_and_admission_refresh_host_inputs(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(6)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            pipeline_depth=1)
        eng.submit(rng.integers(2, 200, size=6), max_new_tokens=3)
        eng._admit()
        assert eng.step() == []  # builds the cached inputs, no finish
        keep0 = eng._keep_dev
        assert not eng._host_dirty
        fin = eng.step()  # emits the last budgeted token -> finish
        assert len(fin) == 1
        assert eng._host_dirty  # finish invalidated the cached mask
        eng.submit(rng.integers(2, 200, size=5), max_new_tokens=2)
        eng._admit()
        eng.step()
        assert eng._keep_dev is not keep0

    def test_budget_is_device_state(self, tiny_lm):
        """The budget vector lives on device and reaches zero exactly when
        the row finishes (device-side max-token exit)."""
        model, params = tiny_lm
        rng = np.random.default_rng(7)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            pipeline_depth=1)
        eng.submit(rng.integers(2, 200, size=6), max_new_tokens=5)
        eng._admit()
        assert int(np.asarray(eng.budget_dev)[0]) == 4
        eng.run()
        assert int(np.asarray(eng.budget_dev)[0]) == 0
        assert not bool(np.asarray(eng._active_dev)[0])


# ------------------------------------------------------- config + telemetry


class TestPipelineConfig:
    def test_rejects_nonpositive_depth(self, tiny_lm):
        model, params = tiny_lm
        with pytest.raises(ValueError, match="pipeline_depth"):
            ServingEngine(model, params, max_batch=1, max_len=64,
                          pipeline_depth=0)

    def test_env_var_sets_default_depth(self, tiny_lm, monkeypatch):
        model, params = tiny_lm
        monkeypatch.setenv("REPRO_SERVING_PIPELINE_DEPTH", "3")
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        assert eng.pipeline_depth == 3
        monkeypatch.delenv("REPRO_SERVING_PIPELINE_DEPTH")
        eng = ServingEngine(model, params, max_batch=1, max_len=64)
        assert eng.pipeline_depth == 2  # shipped default

    def test_stats_report_breakdown(self, tiny_lm):
        model, params = tiny_lm
        rng = np.random.default_rng(8)
        eng = ServingEngine(model, params, max_batch=1, max_len=64,
                            pipeline_depth=2)
        eng.submit(rng.integers(2, 200, size=6), max_new_tokens=6)
        eng.run()
        s = eng.stats()
        assert s["pipeline_depth"] == 2
        assert s["steps"] == len(eng.step_device_wait_s) \
            == len(eng.step_host_s)
        assert s["device_wait_mean_s"] >= 0.0
        assert s["host_mean_s"] >= 0.0


# ----------------------------------------------------- bench schema smoke


class TestBenchSchemaSmoke:
    def test_repo_bench_file_migrates_to_schema8(self):
        """The checked-in BENCH_serving.json must parse and migrate: every
        row of every entry carries pipeline_depth + the step breakdown,
        every entry an audit stamp (null for pre-auditor runs) and a
        telemetry + roofline block (null for pre-observability runs) after
        _migrate_entry."""
        st = pytest.importorskip("benchmarks.serving_throughput")
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
        with open(path) as f:
            doc = json.load(f)
        assert doc["schema"] in (1, 2, 3, 4, 5, 6, 7, 8)
        history = doc["history"] if "history" in doc else [doc]
        for entry in map(st._migrate_entry, history):
            assert entry["mesh"]["devices"] >= 1
            assert "audit" in entry
            audit = entry["audit"]
            if audit is not None:
                assert audit["d2h_per_step"] == 1
                assert audit["donation_ok"] is True
                assert audit["vmem_bytes_per_kernel"]
            assert "telemetry" in entry
            tel = entry["telemetry"]
            if tel is not None:
                assert tel["ttft_s"]["count"] >= 1
                assert tel["occupancy"]["rows_peak"] >= 1
                assert tel["spec"] is None or tel["spec"]["outcomes"]
            assert "roofline" in entry
            if entry["roofline"] is not None:
                assert entry["roofline"]["serving_kernels"]
            assert "faults" in entry
            if entry["faults"] is not None:
                assert set(entry["faults"]) >= {
                    "injected", "quarantined", "retried", "shed"}
            for row in entry["rows"]:
                assert row["pipeline_depth"] >= 1
                assert "step_device_wait_ms" in row
                assert "tok_per_s" in row

    def test_fresh_entries_carry_pipeline_and_packed_kernel(self, tmp_path):
        st = pytest.importorskip("benchmarks.serving_throughput")
        entry = {
            "git_sha": "abc", "mesh": {"dp": 1, "tp": 1, "devices": 1},
            "rows": [{"label": "x+pipe2", "tok_per_s": 1.0,
                      "pipeline_depth": 2, "step_device_wait_ms": 0.1,
                      "step_host_ms": 0.1}],
            "packed_kernel": {"rows_per_pack": 2, "gqa_group": 1,
                              "max_abs_err_vs_oracle": 1e-6},
        }
        doc = st.append_history(entry, path=str(tmp_path / "b.json"))
        assert doc["schema"] == 8
        fresh = doc["history"][-1]
        assert fresh["rows"][0]["pipeline_depth"] == 2
        assert fresh["packed_kernel"]["rows_per_pack"] == 2

    def test_schema5_entry_migrates_telemetry_null(self):
        st = pytest.importorskip("benchmarks.serving_throughput")
        old = {"git_sha": "abc", "mesh": {"dp": 1, "tp": 1, "devices": 1},
               "audit": {"d2h_per_step": 1, "donation_ok": True,
                         "vmem_bytes_per_kernel": {"x": 1}},
               "rows": []}
        mig = st._migrate_entry(old)
        assert mig["telemetry"] is None
        assert mig["roofline"] is None
