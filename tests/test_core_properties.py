"""Property-based tests (hypothesis) for the core invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import (
    achieved_ratio,
    activation_loss,
    compress,
    gram_loss,
    nested_compress,
    rank_for_ratio,
    ratio_for_rank,
    split_rank,
    truncated_svd,
    MatrixSpec,
    uniform_ranks,
)

dims = st.integers(min_value=4, max_value=40)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
ratios = st.floats(min_value=0.05, max_value=0.8)
k1fracs = st.floats(min_value=0.5, max_value=1.0)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=seeds)
def test_truncated_svd_error_never_exceeds_full_rank(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n))
    k = min(m, n) // 2 + 1
    err = np.linalg.norm(a - truncated_svd(a, k).matrix(), "fro")
    s = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(err, np.sqrt(np.sum(s[k:] ** 2)), atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=seeds, k1_frac=k1fracs)
def test_nested_rank_and_storage_invariants(m, n, seed, k1_frac):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n))
    x = rng.standard_normal((n, 3 * n))
    gram = x @ x.T
    k = max(2, min(m, n) // 3)
    f = nested_compress(a, k, "nsvd2", gram=gram, k1_frac=k1_frac,
                        use_randomized=False)
    assert f.rank == k
    assert f.param_count() == (m + n) * k
    # Reconstruction must be finite and loss consistent between the Gram and
    # explicit activation formulations.
    approx = f.matrix()
    assert np.isfinite(approx).all()
    np.testing.assert_allclose(
        gram_loss(a, approx, gram), activation_loss(a, approx, x), rtol=1e-6, atol=1e-8
    )


@settings(max_examples=50, deadline=None)
@given(m=st.integers(32, 4096), n=st.integers(32, 4096), ratio=ratios)
def test_rank_for_ratio_respects_budget(m, n, ratio):
    k = rank_for_ratio(m, n, ratio)
    assert k >= 1
    # Storage never exceeds budget unless clamped to the k=1 floor.
    if k > 1:
        assert (m + n) * k <= (1 - ratio) * m * n
    # And one more rank would overflow it.
    assert (m + n) * (k + 1) > (1 - ratio) * m * n
    # Round-trip consistency.
    assert ratio_for_rank(m, n, k) >= ratio - (m + n) / (m * n)


@settings(max_examples=20, deadline=None)
@given(ratio=ratios, seed=seeds)
def test_uniform_allocation_achieves_ratio(ratio, seed):
    rng = np.random.default_rng(seed)
    specs = [
        MatrixSpec(f"m{i}", int(rng.integers(256, 2048)), int(rng.integers(256, 2048)), "g")
        for i in range(5)
    ]
    ranks = uniform_ranks(specs, ratio)
    achieved = achieved_ratio(specs, ranks)
    # Floor-rounding means achieved >= requested (we remove at least `ratio`),
    # within the one-rank granularity.
    assert achieved >= ratio - 0.02


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 500), k1_frac=st.floats(0.0, 1.0))
def test_split_rank_sum_invariant(k, k1_frac):
    k1, k2 = split_rank(k, k1_frac)
    assert k1 + k2 == k
    assert k1 >= 1
    assert k2 >= 0


@settings(max_examples=10, deadline=None)
@given(seed=seeds)
def test_whitened_loss_dominates_plain_svd_loss_on_activations(seed):
    """Activation-aware compression is never worse than plain SVD *on the
    calibration activations* (it optimizes exactly that objective)."""
    rng = np.random.default_rng(seed)
    m, n, p = 24, 16, 64
    a = rng.standard_normal((m, n))
    scales = np.ones(n)
    scales[:2] = 25.0
    x = rng.standard_normal((n, p)) * scales[:, None]
    gram = x @ x.T
    k = 5
    plain = compress(a, k, "svd", use_randomized=False)
    aware = compress(a, k, "asvd2", gram=gram, damp=0.0, use_randomized=False)
    assert activation_loss(a, aware.matrix(), x) <= activation_loss(
        a, plain.matrix(), x
    ) * (1 + 1e-9)
