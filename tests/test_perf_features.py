"""Tests for the §Perf hillclimb features: int8 KV cache, chunk-local
mamba scan, seq-parallel constraint plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


class TestKVQuant:
    @pytest.mark.parametrize("arch", ["deepseek-67b", "chatglm3-6b"])
    def test_decode_matches_full_forward(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        b, s = 2, 8
        tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
        full, _, _ = model.apply(params, tokens, mode="train")
        cache = model.init_cache(b, 32, kv_quant=True)
        _, cache, _ = model.apply(params, tokens[:, : s - 1], mode="prefill",
                                  cache=cache)
        cl = jnp.full((b,), s - 1, jnp.int32)
        dec, _, _ = model.apply(params, tokens[:, s - 1 :], mode="decode",
                                cache=cache, cache_len=cl)
        np.testing.assert_allclose(
            np.asarray(dec[:, 0]), np.asarray(full[:, -1]), rtol=0.08, atol=0.08
        )

    def test_cache_bytes_halved(self):
        cfg = get_config("deepseek-67b").reduced()
        model = build_model(cfg)

        def nbytes(tree):
            return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

        dense = jax.eval_shape(lambda: model.init_cache(4, 64))
        quant = jax.eval_shape(lambda: model.init_cache(4, 64, kv_quant=True))

        def sdsbytes(tree):
            import numpy as np
            return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                       for x in jax.tree.leaves(tree))

        # fp32 test dtype -> int8 = 4x smaller + per-vector scale overhead
        # (1/hd relative; reduced config hd=8 -> 0.25 + 0.125 = 0.375;
        # production hd=128 -> 0.258).
        assert sdsbytes(quant) < 0.45 * sdsbytes(dense)


class TestMambaChunkLocal:
    def test_chunk_sizes_agree(self):
        """The chunked scan must be chunk-size invariant (the §Perf change
        moved tensor construction inside the body without changing math)."""
        from repro.models.mamba import mamba_apply, mamba_init

        cfg = get_config("jamba-v0.1-52b").reduced()
        params = mamba_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
        y8, _ = mamba_apply(params, x, cfg, chunk=8)
        y16, _ = mamba_apply(params, x, cfg, chunk=16)
        y32, _ = mamba_apply(params, x, cfg, chunk=32)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=2e-4, atol=2e-5)


class TestSeqParallelPlumbing:
    def test_seq_parallel_model_runs_single_device(self):
        cfg = get_config("chatglm3-6b").reduced()
        model = build_model(cfg, seq_parallel=True)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        ref_model = build_model(cfg)
        a, _, _ = model.apply(params, tokens, mode="train")
        b, _, _ = ref_model.apply(params, tokens, mode="train")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
