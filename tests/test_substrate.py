"""Substrate tests: optimizer, grad compression, checkpointing, fault
tolerance, straggler watchdog, elastic plans, data pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import load_checkpoint, save_checkpoint
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import LMDataPipeline, PipelineState
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.optim.grad import compress_grad, decompress_grad, roundtrip
from repro.runtime.elastic import MeshPlan, shrink_plan, validate_batch_divisibility
from repro.runtime.fault import FaultHandler, GuardConfig, HeartbeatMonitor, guarded_update
from repro.runtime.straggler import StepTimeWatchdog, StragglerConfig


class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_state(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip_metric(self):
        params = {"w": jnp.ones((4,))}
        state = init_state(params)
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        _, _, metrics = apply_updates(params, {"w": jnp.full((4,), 100.0)}, state, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)


class TestGradCompression:
    def test_roundtrip_accuracy(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((1024,)) * 0.01, jnp.float32)
        (q, s), err = compress_grad(g)
        deq = decompress_grad((q, s), g.shape)
        cos = float(jnp.dot(deq, g) / (jnp.linalg.norm(deq) * jnp.linalg.norm(g)))
        assert cos > 0.999

    def test_error_feedback_reduces_bias(self):
        """Accumulated error feedback makes the mean of quantized grads
        converge to the true mean (1-bit-Adam property)."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        err = None
        total = jnp.zeros_like(g_true)
        n = 50
        for _ in range(n):
            deq_tree, err = roundtrip({"g": g_true}, err)
            total = total + deq_tree["g"]
        np.testing.assert_allclose(
            np.asarray(total / n), np.asarray(g_true), atol=5e-3
        )

    def test_payload_smaller(self):
        g = jnp.ones((4096,), jnp.float32)
        (q, s), _ = compress_grad(g)
        payload = q.size * 1 + s.size * 4
        assert payload < g.size * 4 / 3.5


class TestCheckpoint:
    def test_roundtrip_nested_tree(self, tmp_path):
        tree = {
            "a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "b": (jnp.ones((2,), jnp.bfloat16), jnp.zeros((1,), jnp.int32)),
        }
        path = str(tmp_path / "ck")
        save_checkpoint(path, tree, extra={"step": 7})
        restored, extra = load_checkpoint(path)
        assert extra["step"] == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]["w"]), np.asarray(tree["a"]["w"]))
        assert restored["b"][0].dtype == jnp.bfloat16

    def test_manager_rotation_and_resume(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for step in (10, 20, 30):
            mgr.save(step, {"w": jnp.full((2,), float(step))}, {"s": step})
        assert mgr.all_steps() == [20, 30]
        tree, extra, step = mgr.restore()
        assert step == 30 and extra["s"] == 30
        assert float(tree["w"][0]) == 30.0

    def test_atomic_save_never_leaves_partial(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        mgr.save(1, {"w": jnp.ones((4,))})
        # a .tmp dir from a crashed save must not be listed
        os.makedirs(str(tmp_path / "step_00000099.tmp"))
        assert mgr.all_steps() == [1]

    def test_elastic_restore_different_mesh(self, tmp_path):
        """Checkpoint saved unsharded restores under any sharding callable."""
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(5, {"w": jnp.arange(16, dtype=jnp.float32)})
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1,), ("data",))
        tree, _, _ = mgr.restore(
            shardings=lambda p: NamedSharding(mesh, P("data"))
        )
        assert tree["w"].shape == (16,)


class TestFaultTolerance:
    def test_guarded_update_keeps_old_on_nan(self):
        old = {"w": jnp.ones((2,))}
        new = {"w": jnp.full((2,), 9.0)}
        kept, bad = guarded_update(jnp.asarray(float("nan")), jnp.asarray(1.0),
                                   new, old, GuardConfig())
        assert bool(bad)
        np.testing.assert_array_equal(np.asarray(kept["w"]), np.asarray(old["w"]))

    def test_guarded_update_passes_good(self):
        old = {"w": jnp.ones((2,))}
        new = {"w": jnp.full((2,), 9.0)}
        kept, bad = guarded_update(jnp.asarray(1.0), jnp.asarray(1.0), new, old,
                                   GuardConfig())
        assert not bool(bad)
        np.testing.assert_array_equal(np.asarray(kept["w"]), np.asarray(new["w"]))

    def test_fault_handler_reload_after_patience(self):
        class FakeMgr:
            pass

        h = FaultHandler(GuardConfig(rollback_patience=3), FakeMgr())
        assert h.observe(True) == "skipped"
        assert h.observe(True) == "skipped"
        assert h.observe(True) == "reload"
        assert h.observe(False) == "ok"

    def test_heartbeat_monitor(self):
        clock = [0.0]
        mon = HeartbeatMonitor(3, timeout_s=10.0, clock=lambda: clock[0])
        clock[0] = 5.0
        mon.beat(0)
        mon.beat(1)
        clock[0] = 12.0
        assert mon.dead_hosts() == [2]


class TestStraggler:
    def test_watchdog_trips_on_consistent_slowness(self):
        clock = [0.0]
        wd = StepTimeWatchdog(StragglerConfig(trip_count=2), clock=lambda: clock[0])
        for _ in range(10):
            wd.step_start(); clock[0] += 1.0
            assert wd.step_end() == "ok"
        wd.step_start(); clock[0] += 5.0
        assert wd.step_end() == "slow"
        wd.step_start(); clock[0] += 5.0
        assert wd.step_end() == "trip"


class TestElastic:
    def test_shrink_keeps_tp(self):
        plan = MeshPlan((2, 16, 16), ("pod", "data", "model"))
        new = shrink_plan(plan, 256)
        assert new is not None
        assert new.shape[new.axes.index("model")] == 16
        assert new.size <= 256

    def test_shrink_impossible(self):
        plan = MeshPlan((16, 16), ("data", "model"))
        assert shrink_plan(plan, 8) is None

    def test_batch_divisibility(self):
        plan = MeshPlan((8, 16), ("data", "model"))
        assert validate_batch_divisibility(256, plan, ("data",))
        assert not validate_batch_divisibility(100, plan, ("data",))


class TestPipeline:
    def test_deterministic_restart(self):
        p1 = LMDataPipeline(512, 4, 32, PipelineState(seed=3, step=0))
        batches = [next(p1)["tokens"] for _ in range(5)]
        # Restart from step 3.
        p2 = LMDataPipeline(512, 4, 32, PipelineState(seed=3, step=3))
        np.testing.assert_array_equal(np.asarray(next(p2)["tokens"]),
                                      np.asarray(batches[3]))

    def test_domains_differ(self):
        from repro.data.synth import DomainSampler

        s = DomainSampler(512, seed=0)
        a = s.batch("en_a", 4, 64)
        z = s.batch("zh", 4, 64)
        # Disjoint-ish token ranges.
        assert a.max() < 512 // 2 + 1
        assert z.min() >= 512 // 4
