"""Unit tests for the runtime fault-tolerance primitives the serving
layer builds on: the jit-side step guard, the host-side fault handler,
the heartbeat monitor (injected clocks), and the step-time watchdog's
clean-median discipline."""

import jax
import jax.numpy as jnp
import pytest

from repro.runtime.fault import (
    FaultHandler,
    GuardConfig,
    HeartbeatMonitor,
    guarded_update,
)
from repro.runtime.straggler import StepTimeWatchdog, StragglerConfig


# ------------------------------------------------------------ step guard


class TestGuardedUpdate:
    def _trees(self):
        new = {"w": jnp.full((3,), 2.0), "b": jnp.full((2,), 4.0)}
        old = {"w": jnp.full((3,), 1.0), "b": jnp.full((2,), 3.0)}
        return new, old

    def test_clean_step_takes_new_tree(self):
        new, old = self._trees()
        kept, bad = guarded_update(jnp.float32(1.0), jnp.float32(0.5),
                                   new, old, GuardConfig())
        assert not bool(bad)
        assert jnp.array_equal(kept["w"], new["w"])

    @pytest.mark.parametrize("loss,gnorm", [
        (jnp.nan, 0.5),          # non-finite loss
        (jnp.inf, 0.5),
        (1e9, 0.5),              # divergent loss
        (1.0, jnp.nan),          # non-finite grad
        (1.0, 1e9),              # exploding grad
    ])
    def test_corrupt_step_keeps_old_tree(self, loss, gnorm):
        new, old = self._trees()
        kept, bad = guarded_update(jnp.float32(loss), jnp.float32(gnorm),
                                   new, old, GuardConfig())
        assert bool(bad)
        assert jnp.array_equal(kept["w"], old["w"])
        assert jnp.array_equal(kept["b"], old["b"])

    def test_guard_works_under_jit(self):
        cfg = GuardConfig()

        @jax.jit
        def step(loss, new, old):
            return guarded_update(loss, jnp.float32(0.0), new, old, cfg)

        new, old = self._trees()
        kept, bad = step(jnp.float32(jnp.nan), new, old)
        assert bool(bad) and jnp.array_equal(kept["w"], old["w"])
        kept, bad = step(jnp.float32(1.0), new, old)
        assert not bool(bad) and jnp.array_equal(kept["w"], new["w"])


class TestFaultHandler:
    def test_reload_cadence(self):
        h = FaultHandler(GuardConfig(rollback_patience=3), manager=object())
        assert h.observe(False) == "ok"
        assert [h.observe(True) for _ in range(3)] == \
            ["skipped", "skipped", "reload"]
        assert (h.total_bad, h.reloads, h.consecutive_bad) == (3, 1, 0)
        # A clean step resets the consecutive count.
        assert h.observe(True) == "skipped"
        assert h.observe(False) == "ok"
        assert h.consecutive_bad == 0

    def test_no_manager_never_reloads(self):
        h = FaultHandler(GuardConfig(rollback_patience=1), manager=None)
        assert all(h.observe(True) == "skipped" for _ in range(5))
        assert h.reloads == 0 and h.total_bad == 5


# ------------------------------------------------------------ heartbeats


class TestHeartbeatMonitor:
    def test_dead_hosts_with_injected_clock(self):
        t = {"now": 0.0}
        mon = HeartbeatMonitor(3, timeout_s=10.0, clock=lambda: t["now"])
        assert mon.healthy()
        t["now"] = 8.0
        mon.beat(0)
        mon.beat(2)
        t["now"] = 15.0                 # host 1 last seen at t=0
        assert mon.dead_hosts() == [1]
        assert not mon.healthy()
        mon.beat(1)
        assert mon.healthy()

    def test_unknown_host_raises(self):
        mon = HeartbeatMonitor(2)
        with pytest.raises(KeyError, match="unknown host"):
            mon.beat(5)


# ---------------------------------------------------------- watchdog


class TestStepTimeWatchdog:
    def test_warmup_is_always_ok(self):
        wd = StepTimeWatchdog(StragglerConfig())
        # Fewer than 8 observations: no baseline, everything is 'ok'.
        assert all(wd.observe(d) == "ok" for d in [0.01] * 7 + [5.0])

    def test_slow_then_trip(self):
        cfg = StragglerConfig(slow_factor=2.5, trip_count=3)
        wd = StepTimeWatchdog(cfg)
        for _ in range(8):
            wd.observe(0.01)
        assert wd.observe(0.1) == "slow"
        assert wd.observe(0.1) == "slow"
        assert wd.observe(0.1) == "trip"
        assert wd.trips == 1
        # The counter reset on trip: the next slow step starts over.
        assert wd.observe(0.1) == "slow"

    def test_clean_median_excludes_flagged_steps(self):
        """Flagged durations must NOT enter the history: sustained
        degradation would otherwise drag the median up until the
        watchdog stopped tripping on it."""
        wd = StepTimeWatchdog(StragglerConfig(slow_factor=2.0))
        for _ in range(8):
            wd.observe(0.01)
        for _ in range(20):             # sustained 10x degradation
            assert wd.observe(0.1) != "ok"
        assert wd.median_step == pytest.approx(0.01)

    def test_fast_step_resets_suspicion(self):
        wd = StepTimeWatchdog(StragglerConfig(trip_count=3))
        for _ in range(8):
            wd.observe(0.01)
        assert wd.observe(0.1) == "slow"
        assert wd.observe(0.01) == "ok"     # resets the streak
        assert wd.observe(0.1) == "slow"    # starts over, no trip
        assert wd.trips == 0

    def test_start_end_bracketing(self):
        t = {"now": 0.0}
        wd = StepTimeWatchdog(StragglerConfig(), clock=lambda: t["now"])
        wd.step_start()
        t["now"] = 0.02
        assert wd.step_end() == "ok"
        assert wd.history == [0.02]

    def test_history_stays_bounded(self):
        cfg = StragglerConfig(window=8)
        wd = StepTimeWatchdog(cfg)
        for _ in range(1000):
            wd.observe(0.01)
        assert len(wd.history) <= 4 * cfg.window
